"""Multi-device behaviour via subprocesses (XLA_FLAGS host device count).

These run the real shard_map/pjit paths on 8 simulated devices: distributed
mining parity, EP-MoE parity vs single device, elastic checkpoint reshard,
and a miniature dry-run through the production launcher code path.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, n_devices: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_mining_parity_on_8_devices():
    out = run_py("""
        import numpy as np, json
        from repro.core import mine, sequential_apriori
        rng = np.random.default_rng(0)
        base = rng.random((4, 20)) < 0.4
        txns = []
        for _ in range(160):
            pat = base[rng.integers(4)]
            row = np.where(rng.random(20) < 0.85, pat, rng.random(20) < 0.1)
            t = np.nonzero(row)[0].tolist() or [0]
            txns.append(t)
        oracle = sequential_apriori(txns, 0.3)
        import jax
        assert len(jax.devices()) == 8
        for algo in ["spc", "optimized_vfpc"]:
            res = mine(txns, n_items=20, min_sup=0.3, algorithm=algo)
            assert res.itemsets() == oracle, algo
        print("PARITY_OK")
    """)
    assert "PARITY_OK" in out


def test_ep_moe_matches_single_device():
    out = run_py("""
        import jax, numpy as np, dataclasses
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.models.moe import moe_init, moe_apply, _moe_apply_global
        from repro.models.model import ShardCtx
        from repro import sharding
        from repro.compat import make_mesh
        cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b", smoke=True),
                                  capacity_factor=8.0)
        p, _ = moe_init(jax.random.PRNGKey(0), cfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = ShardCtx(mesh, sharding.make_rules())
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32).astype(jnp.bfloat16)
        y_ep, aux_ep = jax.jit(lambda p, x: moe_apply(p, x, cfg, ctx))(p, x)
        y_g, aux_g = jax.jit(lambda p, x: _moe_apply_global(p, x, cfg, None))(p, x)
        err = float(jnp.max(jnp.abs(y_ep.astype(jnp.float32) - y_g.astype(jnp.float32))))
        scale = float(jnp.max(jnp.abs(y_g.astype(jnp.float32)))) + 1e-9
        assert err / scale < 0.05, (err, scale)
        print("EP_OK", err/scale)
    """)
    assert "EP_OK" in out


def test_elastic_reshard_8_to_4():
    out = run_py("""
        import jax, os, tempfile, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.optim import AdamWConfig
        from repro.train import init_train_state, save_checkpoint
        from repro.train.elastic import restore_elastic
        from repro import sharding
        from repro.compat import make_mesh
        model = build_model(get_config("smollm-135m", smoke=True))
        opt = AdamWConfig()
        rules = sharding.make_rules()
        mesh8 = make_mesh((4, 2), ("data", "model"))
        state = init_train_state(model, opt, jax.random.PRNGKey(0), mesh8, rules)
        d = tempfile.mkdtemp()
        save_checkpoint(d, 5, state)
        # restore onto a DIFFERENT mesh (2x2 = "scale down to 4 devices")
        mesh4 = make_mesh((2, 2), ("data", "model"))
        tmpl = jax.tree.map(lambda x: x, state)
        state4, step = restore_elastic(d, model, opt, mesh4, rules, tmpl)
        assert step == 5
        a = np.asarray(jax.device_get(state["params"]["embed"]["table"]), np.float32)
        b = np.asarray(jax.device_get(state4["params"]["embed"]["table"]), np.float32)
        assert (a == b).all()
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_mini_dryrun_multipod_codepath():
    """The production dryrun code path on a small mesh: lower+compile train
    and decode for a smoke arch on (pod, data, model) axes."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, ShapeConfig
        from repro.models import build_model
        from repro import sharding
        from repro.launch.dryrun import build_step
        from repro.compat import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = sharding.make_rules()
        model = build_model(get_config("smollm-135m", smoke=True))
        for shape in [ShapeConfig("t", 32, 8, "train"),
                      ShapeConfig("p", 32, 8, "prefill"),
                      ShapeConfig("d", 64, 8, "decode")]:
            fn, ex, _, _ = build_step(model, shape, mesh, rules)
            compiled = fn.lower(*ex).compile()
            assert compiled.memory_analysis() is not None
        print("MINIDRY_OK")
    """)
    assert "MINIDRY_OK" in out


def test_2d_candidate_decomposition():
    """Beyond-paper: candidates sharded over `model` while transactions shard
    over `data` (2-D MapReduce decomposition) — identical results."""
    out = run_py("""
        import jax, numpy as np
        from repro.core import mine, sequential_apriori
        from repro.core.mapreduce import MapReduceRuntime
        rng = np.random.default_rng(5)
        base = rng.random((4, 20)) < 0.4
        txns = []
        for _ in range(120):
            pat = base[rng.integers(4)]
            row = np.where(rng.random(20) < 0.85, pat, rng.random(20) < 0.1)
            txns.append(np.nonzero(row)[0].tolist() or [0])
        oracle = sequential_apriori(txns, 0.3)
        from repro.compat import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        rt = MapReduceRuntime(mesh=mesh, cand_axis="model")
        res = mine(txns, n_items=20, min_sup=0.3, algorithm="optimized_vfpc",
                   runtime=rt)
        assert res.itemsets() == oracle
        print("2D_OK")
    """)
    assert "2D_OK" in out


def test_2d_candidate_decomposition_narrow_shards():
    """cand_axis wide enough that per-shard candidate counts are NOT a
    multiple of 32 (256-row bucket / 16 shards = 16): the fused keep mask
    must survive the shard concatenation (regression: per-shard bit-packing
    padded each shard to a word boundary and corrupted the global mask)."""
    out = run_py("""
        import jax, numpy as np
        from repro.core import mine, sequential_apriori
        from repro.core.mapreduce import MapReduceRuntime
        from repro.compat import make_mesh
        rng = np.random.default_rng(9)
        base = rng.random((4, 20)) < 0.4
        txns = []
        for _ in range(96):
            pat = base[rng.integers(4)]
            row = np.where(rng.random(20) < 0.85, pat, rng.random(20) < 0.1)
            txns.append(np.nonzero(row)[0].tolist() or [0])
        oracle = sequential_apriori(txns, 0.3)
        mesh = make_mesh((1, 16), ("data", "model"))
        rt = MapReduceRuntime(mesh=mesh, cand_axis="model", autotune=False)
        res = mine(txns, n_items=20, min_sup=0.3, algorithm="optimized_vfpc",
                   runtime=rt)
        assert res.itemsets() == oracle
        print("2D_NARROW_OK")
    """, n_devices=16)
    assert "2D_NARROW_OK" in out


def test_2d_mesh_parity_all_families():
    """The runtime-owned (data, cand) mesh at both (4,2) and (2,4) splits,
    across impl families including the matmul twins — every shape must be
    bit-identical to the sequential oracle (DESIGN.md §11)."""
    out = run_py("""
        import numpy as np
        from repro.core import mine, sequential_apriori
        from repro.core.mapreduce import MapReduceRuntime
        from repro.compat import make_mesh
        rng = np.random.default_rng(11)
        base = rng.random((4, 24)) < 0.4
        txns = []
        for _ in range(160):
            pat = base[rng.integers(4)]
            row = np.where(rng.random(24) < 0.85, pat, rng.random(24) < 0.1)
            txns.append(np.nonzero(row)[0].tolist() or [0])
        oracle = sequential_apriori(txns, 0.25)
        for split in [(4, 2), (2, 4)]:
            for impl in ["jnp", "matmul", "vertical", "vertical_matmul"]:
                mesh = make_mesh(split, ("data", "cand"))
                rt = MapReduceRuntime(mesh=mesh, impl=impl, cand_axis="cand")
                res = mine(txns, n_items=24, min_sup=0.25,
                           algorithm="optimized_etdpc", runtime=rt,
                           elastic=False)
                assert res.itemsets() == oracle, (split, impl)
        print("MESH2D_FAMILIES_OK")
    """)
    assert "MESH2D_FAMILIES_OK" in out


def test_repartition_mid_mine_parity():
    """Elastic repartitioning mid-mine: scripted choose_mesh walks the run
    through (8,1) → (2,4) → (4,2) splits and results stay bit-identical,
    with the re-layouts visible in MiningResult.repartitions."""
    out = run_py("""
        import numpy as np
        from repro.core import mine, sequential_apriori
        from repro.core.mapreduce import MapReduceRuntime
        from repro.costmodel import CostController
        from repro.costmodel.model import CostModel
        from repro.launch.mesh import make_mining_mesh
        rng = np.random.default_rng(12)
        base = rng.random((4, 24)) < 0.4
        txns = []
        for _ in range(200):
            pat = base[rng.integers(4)]
            row = np.where(rng.random(24) < 0.85, pat, rng.random(24) < 0.1)
            txns.append(np.nonzero(row)[0].tolist() or [0])
        oracle = sequential_apriori(txns, 0.25)
        rt = MapReduceRuntime(mesh=make_mining_mesh(8, 1), impl="jnp")
        ctl = CostController(model=CostModel(persist=False))
        script = iter([(2, 4), (4, 2)])
        ctl.choose_mesh = lambda *a, **k: next(script, None)
        res = mine(txns, n_items=24, min_sup=0.25,
                   algorithm="optimized_etdpc", runtime=rt,
                   controller=ctl, elastic=True)
        assert res.repartitions == 2, res.repartitions
        assert rt.mesh_split == (4, 2)
        assert res.itemsets() == oracle
        print("REPARTITION_OK")
    """)
    assert "REPARTITION_OK" in out


def test_retry_after_injected_failure():
    """A counting job that dies mid-phase (injected via count_hook) is
    recovered by rescatter + re-dispatch on the 2-D mesh, bit-identically."""
    out = run_py("""
        import numpy as np
        from repro.core import mine, sequential_apriori
        from repro.core.mapreduce import MapReduceRuntime
        from repro.launch.mesh import make_mining_mesh
        rng = np.random.default_rng(13)
        base = rng.random((4, 24)) < 0.4
        txns = []
        for _ in range(160):
            pat = base[rng.integers(4)]
            row = np.where(rng.random(24) < 0.85, pat, rng.random(24) < 0.1)
            txns.append(np.nonzero(row)[0].tolist() or [0])
        oracle = sequential_apriori(txns, 0.25)
        calls = {"n": 0}
        def fail_twice(event, k):
            if event == "count_dispatch":
                calls["n"] += 1
                if calls["n"] in (2, 3):
                    raise RuntimeError("injected shard failure")
        rt = MapReduceRuntime(mesh=make_mining_mesh(4, 2), impl="jnp",
                              cand_axis="cand")
        res = mine(txns, n_items=24, min_sup=0.25,
                   algorithm="optimized_etdpc", runtime=rt,
                   elastic=False, count_hook=fail_twice)
        assert res.retries == 2, res.retries
        assert res.itemsets() == oracle
        # beyond max_retries the failure propagates
        calls["n"] = 0
        def always_fail(event, k):
            if event == "count_dispatch":
                raise RuntimeError("dead shard")
        try:
            mine(txns, n_items=24, min_sup=0.25, runtime=rt,
                 elastic=False, count_hook=always_fail, max_retries=1)
            raise AssertionError("expected failure to propagate")
        except RuntimeError as e:
            assert "dead shard" in str(e)
        print("RETRY_OK")
    """)
    assert "RETRY_OK" in out


def test_balanced_shards_mining():
    """Width-balanced sharding (static straggler mitigation) keeps results exact."""
    out = run_py("""
        import numpy as np
        from repro.core import mine, sequential_apriori
        rng = np.random.default_rng(6)
        txns = [sorted(rng.choice(24, rng.integers(2, 14), replace=False).tolist())
                for _ in range(200)]
        oracle = sequential_apriori(txns, 0.2)
        res = mine(txns, n_items=24, min_sup=0.2, algorithm="vfpc",
                   balance_shards_by_width=True)
        assert res.itemsets() == oracle
        print("BALANCED_OK")
    """)
    assert "BALANCED_OK" in out


def test_decode_profile_parity():
    """The §Perf `decode` sharding profile (weights replicated over data,
    KV-seq on model) preserves decode semantics: prefill + decode-step logits
    match the unsharded run up to bf16 reduction-order noise."""
    out = run_py("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.model import ShardCtx
        from repro import sharding
        cfg = get_config("smollm-135m", smoke=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, S, steps = 4, 8, 3
        toks = np.random.default_rng(0).integers(1, cfg.vocab_size, (B, S)).astype(np.int32)

        forced = np.random.default_rng(1).integers(
            1, cfg.vocab_size, (steps, B)).astype(np.int32)

        def rollout(ctx):
            # teacher-forced so numeric tie-flips cannot compound
            batch = {"tokens": jnp.asarray(toks)}
            lgs = []
            lg, caches = model.prefill(params, batch, cache_len=S+steps, ctx=ctx)
            lgs.append(np.asarray(lg))
            for t in range(steps - 1):
                cur = jnp.asarray(forced[t])
                lg, caches = model.decode_step(params, caches, cur[:, None],
                                               jnp.full((B,), S+t, jnp.int32), ctx)
                lgs.append(np.asarray(lg))
            return np.stack(lgs)

        base = rollout(ShardCtx(None, None))
        from repro.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = sharding.make_rules("decode")
        sharded = rollout(ShardCtx(mesh, rules))
        err = np.abs(base - sharded)[:, :, :cfg.vocab_size].max()
        assert err < 0.05, err
        print("DECODE_PROFILE_OK", err)
    """)
    assert "DECODE_PROFILE_OK" in out


def test_speedup_harness_runs():
    """Mining wall time measured at 1 and 4 devices (speedup bench harness)."""
    for n in [1, 4]:
        out = run_py(f"""
            import time, numpy as np
            from repro.data import dataset_by_name
            from repro.core import mine
            txns, n_items = dataset_by_name("mushroom", scale=0.05)
            t0 = time.perf_counter()
            res = mine(txns, n_items=n_items, min_sup=0.4,
                       algorithm="optimized_vfpc")
            print("TIME", time.perf_counter() - t0, res.n_phases)
        """, n_devices=n)
        assert "TIME" in out
