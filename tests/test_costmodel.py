"""Cost-model subsystem (DESIGN.md §9): affine-fit calibration, controller
decision primitives, paper-policy bit-identity on recorded traces, persisted
warm-starts, and the autotune cache's device-kind key migration."""

import json

import numpy as np
import pytest

from repro.core.policy import (ALGORITHMS, DPCPolicy, ETDPCPolicy, FPCPolicy,
                               MeasuredPolicy, PhaseStats, SPCPolicy,
                               VFPCPolicy)
from repro.costmodel import CostController, CostModel, device_key
from repro.costmodel.model import (MIN_AFFINE_SAMPLES, OUTLIER_FACTOR,
                                   AffineFit)


def S(c, f, e):
    return PhaseStats(n_candidates=c, n_frequent_last=f, elapsed=e)


def _fresh_controller(**kw):
    return CostController(CostModel(persist=False), **kw)


def _calibrate_counts(ctl, a=1e-3, b=1e-9, counts=(100, 400, 1600, 6400)):
    """Feed exact affine timings t = a + b·ops so the fit recovers (a, b)."""
    for c in counts:
        ctl.observe_count(c, a + b * ctl._count_ops(c))
    return ctl


# ---------------------------------------------------------------------------
# AffineFit: calibration convergence, monotonicity, decay, outlier rejection
# ---------------------------------------------------------------------------

def test_affine_fit_converges_on_synthetic_timings():
    rng = np.random.default_rng(0)
    a, b = 5e-3, 2e-9
    fit = AffineFit()
    for x in rng.uniform(1e5, 1e8, 40):
        fit.observe(x, (a + b * x) * rng.uniform(0.99, 1.01))
    fa, fb = fit.coeffs()
    assert fa == pytest.approx(a, rel=0.25)
    assert fb == pytest.approx(b, rel=0.05)


def test_affine_fit_ratio_estimate_below_min_samples():
    """One sample answers immediately — through the origin, no intercept."""
    fit = AffineFit()
    fit.observe(1000.0, 0.01)
    assert fit.coeffs() == (0.0, pytest.approx(1e-5))
    assert fit.predict(2000.0) == pytest.approx(0.02)


def test_predictions_monotone_in_ops():
    """Slope is clamped ≥ 0: a wider phase never predicts cheaper."""
    rng = np.random.default_rng(1)
    fit = AffineFit()
    # noise-dominated, slightly anti-correlated samples
    for x in rng.uniform(1e3, 1e6, 20):
        fit.observe(x, rng.uniform(0.009, 0.011) - 1e-9 * x)
    xs = np.linspace(1e3, 1e7, 50)
    preds = [fit.predict(x) for x in xs]
    assert all(p2 >= p1 for p1, p2 in zip(preds, preds[1:]))


def test_outlier_spike_rejected_after_calibration():
    """A compile-spike sample far above the fit's own prediction is dropped;
    moderate regime drift is still learned (decay handles it)."""
    fit = AffineFit()
    for x in (1e6, 2e6, 4e6, 8e6):
        fit.observe(x, 1e-3 + 1e-9 * x)
    n0, before = fit.n, fit.predict(1e6)
    fit.observe(1e6, OUTLIER_FACTOR * 100 * before)      # jit spike
    assert fit.n == n0 and fit.predict(1e6) == before
    fit.observe(1e6, 2 * before)                         # plausible sample
    assert fit.n == n0 + 1


def test_decay_tracks_regime_change():
    """After a sustained slowdown (below the spike-rejection factor) the
    decayed fit re-converges on the new slope instead of averaging the
    regimes forever."""
    fit = AffineFit()
    xs = [1e6, 3e6, 9e6, 27e6]
    for x in xs * 3:
        fit.observe(x, 1e-9 * x)
    for x in xs * 8:                     # new regime: 5× slower per op
        fit.observe(x, 5e-9 * x)
    assert fit.predict(1e7) == pytest.approx(0.05, rel=0.25)


def test_fit_ignores_degenerate_samples():
    fit = AffineFit()
    fit.observe(0.0, 1.0)
    fit.observe(-5.0, 1.0)
    fit.observe(float("nan"), 1.0)
    fit.observe(1.0, float("inf"))
    fit.observe(1.0, -0.1)
    assert fit.n == 0 and fit.coeffs() is None


# ---------------------------------------------------------------------------
# CostModel: persistence + schema gating
# ---------------------------------------------------------------------------

def test_costmodel_persists_and_warm_starts(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_COSTMODEL_CACHE", str(tmp_path / "cm.json"))
    m = CostModel(persist=True)
    for i in range(1, 5):
        m.observe("k", 1e6 * i, 1e-3 * i)
    disk = json.load(open(tmp_path / "cm.json"))
    assert disk["schema"] == CostModel.SCHEMA and "k" in disk["fits"]
    m2 = CostModel(persist=True)         # next process warm-starts the fit
    assert m2.n_samples("k") == 4
    assert m2.predict("k", 2e6) == pytest.approx(m.predict("k", 2e6))


def test_costmodel_discards_mismatched_schema(tmp_path, monkeypatch):
    path = tmp_path / "cm.json"
    monkeypatch.setenv("REPRO_COSTMODEL_CACHE", str(path))
    path.write_text(json.dumps(
        {"schema": CostModel.SCHEMA - 1,
         "fits": {"k": {"n": 3, "sx": 1, "sy": 1, "sxx": 1, "sxy": 1}}}))
    assert CostModel(persist=True).n_samples("k") == 0


def test_device_key_has_backend_and_kind():
    key = device_key()
    backend, kind = key.split(":", 1)
    assert backend and kind


# ---------------------------------------------------------------------------
# Paper policies: bit-identical decisions on a recorded PhaseStats trace
# ---------------------------------------------------------------------------

# a recorded optimized-run trajectory: explosive level 2-3, then collapse
TRACE = [S(192, 60, 0.008), S(1770, 131, 0.012), S(34220, 97, 0.065),
         S(1545, 40, 0.009), S(383, 12, 0.003), S(91, 3, 0.002)]


def _replay(policy):
    out = [policy.decide(None, None)]
    out += [policy.decide(TRACE[i], TRACE[i - 1] if i else None)
            for i in range(len(TRACE))]
    return out


def test_paper_policies_bit_identical_on_recorded_trace():
    """The refactor must not move the paper baselines: exact golden decision
    sequences for every transcription on one recorded trace."""
    golden = {
        SPCPolicy(): [("width", 1)] * 7,
        FPCPolicy(): [("width", 3)] * 7,
        DPCPolicy(): [("budget_alpha", a)
                      for a in (1.0, 2.0, 2.0, 1.0, 2.0, 2.0, 2.0)],
        VFPCPolicy(): [("width", w) for w in (2, 2, 2, 2, 5, 8, 11)],
        ETDPCPolicy(): [("budget_alpha", a)
                        for a in (1.0, 2.0, 3.0, 1.0, 3.0, 3.0, 3.0)],
    }
    for policy, want in golden.items():
        assert _replay(policy) == want, policy.name


def test_algorithm_registry_unchanged_plus_measured():
    for name in ("spc", "fpc", "dpc", "vfpc", "etdpc",
                 "optimized_vfpc", "optimized_etdpc", "measured"):
        assert name in ALGORITHMS
    assert ALGORITHMS["measured"] == (MeasuredPolicy, True)


# ---------------------------------------------------------------------------
# CostController: choose_width
# ---------------------------------------------------------------------------

def test_measured_policy_falls_back_to_etdpc_until_calibrated():
    ctl = _fresh_controller()
    pol, ref = MeasuredPolicy(controller=ctl), ETDPCPolicy()
    for prev, prev2 in [(None, None), (TRACE[1], TRACE[0]),
                        (TRACE[2], TRACE[1])]:
        assert pol.decide(prev, prev2) == ref.decide(prev, prev2)
    assert ctl.decisions == []           # fallback decisions are the paper's


def test_choose_width_prices_overhead_against_unpruned_work():
    """On a growing candidate trajectory: high per-job overhead → fuse;
    negligible overhead → width 1 (the un-pruned extra candidates are all
    cost, no saving)."""
    prev, prev2 = S(1545, 40, 0.009), S(383, 12, 0.003)    # growth ≈ 4×
    fuse = _calibrate_counts(_fresh_controller(max_width=8), a=0.05, b=1e-12)
    assert fuse.choose_width(prev, prev2) > 1.0
    lean = _calibrate_counts(_fresh_controller(max_width=8), a=0.0, b=1e-6)
    assert lean.choose_width(prev, prev2) == 1.0


def test_choose_width_post_job1_uses_binomial_lattice():
    """At the post-Job1 decision the un-pruned level 2+j is exactly
    C(|L1|, 2+j); with |L1| large the binomial mid-levels dwarf any job
    overhead, so the controller must refuse to fuse."""
    ctl = _fresh_controller(max_width=8)
    ctl.set_count_context(n_txns=1000, n_words=6, impl="default")
    _calibrate_counts(ctl, a=0.005, b=3e-10)
    assert ctl.choose_width(S(192, 60, 0.008), None) == 1.0
    d = ctl.decisions[-1]
    assert d.site == "pass_width" and d.chosen == 1
    # predicted cost is strictly increasing in fused width on this lattice
    costs = [d.predicted[w] for w in sorted(d.predicted)]
    assert all(c2 > c1 for c1, c2 in zip(costs, costs[1:]))


def test_choose_width_alpha_covers_chosen_levels():
    """The returned α is a *budget*: with the drivers' overshoot-by-one-level
    semantics, α·|L| must fall between the cumulative candidate estimates of
    the chosen width and its neighbours."""
    ctl = _calibrate_counts(_fresh_controller(max_width=8), a=0.05, b=1e-12)
    prev, prev2 = S(1545, 40, 0.009), S(383, 12, 0.003)
    alpha = ctl.choose_width(prev, prev2)
    w = ctl.decisions[-1].chosen
    growth = max(min(prev.n_candidates / prev2.n_candidates, 16.0), 0.25)
    est = [prev.n_candidates * growth ** (j + 1) for j in range(w)]
    assert sum(est[:w - 1]) <= alpha * prev.n_frequent_last <= sum(est)


def test_observe_count_backfills_decision_telemetry():
    ctl = _calibrate_counts(_fresh_controller(max_width=4), a=0.05, b=1e-12)
    ctl.choose_width(S(1545, 40, 0.009), S(383, 12, 0.003))
    assert ctl.decisions[-1].measured is None
    ctl.observe_count(500, 0.042)
    assert ctl.decisions[-1].measured == pytest.approx(0.042)
    rows = ctl.decision_rows()
    assert rows[-1]["site"] == "pass_width"
    assert str(rows[-1]["chosen"]) in rows[-1]["predicted"]


# ---------------------------------------------------------------------------
# CostController: elastic mesh + shard-balance decisions (DESIGN.md §11)
# ---------------------------------------------------------------------------

def test_choose_mesh_uncalibrated_or_single_device_is_none():
    ctl = _fresh_controller()
    ctl.set_count_context(n_txns=1000, n_words=4, impl="jnp")
    assert ctl.choose_mesh(1000, n_devices=8) is None      # no fit yet
    _calibrate_counts(ctl)
    assert ctl.choose_mesh(1000, n_devices=1) is None      # nothing to split


def test_choose_mesh_prefers_cand_split_when_candidates_explode():
    # small T: the per-device candidate-payload + psum transfer terms
    # dominate, so sharding candidates must win once |C| is large
    ctl = _fresh_controller()
    ctl.set_count_context(n_txns=2048, n_words=4, impl="jnp",
                          n_data_shards=8, n_cand_shards=1)
    _calibrate_counts(ctl, a=1e-3, b=1e-9,
                      counts=(100, 400, 1600, 6400, 25600))
    split = ctl.choose_mesh(10**6, n_devices=8, current=(8, 1))
    assert split is not None and split[1] > 1, split
    dec = ctl.decisions[-1]
    assert dec.site == "mesh_split"
    assert f"{split[0]}x{split[1]}" in dec.predicted
    # every factorization of 8 was priced
    assert set(dec.predicted) == {"1x8", "2x4", "4x2", "8x1"}


def test_choose_mesh_hysteresis_keeps_current_split_on_small_jobs():
    ctl = _fresh_controller()
    ctl.set_count_context(n_txns=2048, n_words=4, impl="jnp",
                          n_data_shards=8, n_cand_shards=1)
    _calibrate_counts(ctl, a=1e-3, b=1e-9)
    # tiny job: split costs are within the hysteresis band → stay put
    assert ctl.choose_mesh(64, n_devices=8, current=(8, 1)) == (8, 1)


def test_repartition_penalty_calibrates_and_prices_moves():
    ctl = _fresh_controller()
    ctl.set_count_context(n_txns=1000, n_words=4, impl="jnp")
    assert ctl.predict_repartition(1000, 4) is None
    ctl.observe_repartition(1000, 4, 0.02)
    assert ctl.predict_repartition(1000, 4) == pytest.approx(0.02)
    assert ctl.predict_repartition(2000, 4) == pytest.approx(0.04)


def test_should_rebalance_prices_skew_against_repack_cost():
    ctl = _fresh_controller()
    ctl.set_count_context(n_txns=4096, n_words=4, impl="jnp")
    # uncalibrated count fit: keep the default (never fire)
    assert not ctl.should_rebalance([100.0, 900.0], est_candidates=1000)
    _calibrate_counts(ctl, a=0.1, b=1e-9)   # expensive jobs
    ctl.observe_rebalance(4096, 1e-4)       # cheap re-pack
    assert ctl.should_rebalance([100.0, 900.0], est_candidates=1000)
    assert ctl.decisions[-1].site == "rebalance"
    # no skew → no waste → never worth the re-pack
    assert not ctl.should_rebalance([500.0, 500.0], est_candidates=1000)
    # skewed but the re-pack now costs more than the waste
    ctl2 = _fresh_controller()
    ctl2.set_count_context(n_txns=4096, n_words=4, impl="jnp")
    _calibrate_counts(ctl2, a=1e-6, b=1e-12)  # cheap jobs
    ctl2.observe_rebalance(4096, 10.0)        # pathological re-pack
    assert not ctl2.should_rebalance([100.0, 900.0], est_candidates=1000)


def test_count_ops_split_pricing_levers():
    """The split-dependent ops terms behave as designed: candidate sharding
    shrinks per-shard ops, and the psum term penalizes wide data splits."""
    ctl = _fresh_controller()
    ctl.set_count_context(n_txns=1024, n_words=4, impl="jnp")
    base = ctl._count_ops(10**5, split=(1, 1))
    assert ctl._count_ops(10**5, split=(1, 8)) < base
    # equal-product splits price differently (cand split cheaper at big C)
    assert (ctl._count_ops(10**5, split=(1, 8))
            < ctl._count_ops(10**5, split=(8, 1)))


# ---------------------------------------------------------------------------
# CostController: remine + speculation + fusion primitives
# ---------------------------------------------------------------------------

def test_predict_remine_extrapolates_from_one_sample():
    """The cold-start fix: one tiny init-time mine already scales with the
    window instead of freezing the estimate."""
    ctl = _fresh_controller()
    ctl.observe_remine(100, 0.01)
    assert ctl.predict_remine(100) == pytest.approx(0.01)
    assert ctl.predict_remine(1000) == pytest.approx(0.10)


def test_should_remine_threshold_and_telemetry():
    ctl = _fresh_controller()
    ctl.observe_remine(100, 0.01)
    common = dict(window_rows=1000, staleness_factor=1.0)   # predicted 0.1 s
    assert not ctl.should_remine(drift=0.5, staleness_seconds=0.1, **common)
    assert ctl.should_remine(drift=0.5, staleness_seconds=0.3, **common)
    assert ctl.decisions[-1].site == "remine"
    # uncalibrated + no fallback: never fires
    cold = _fresh_controller()
    assert not cold.should_remine(drift=9.0, staleness_seconds=9.0, **common)
    assert cold.should_remine(drift=9.0, staleness_seconds=9.0,
                              fallback_seconds=0.1, **common)


def test_should_speculate_gates_on_predicted_window():
    # ops basis includes the device→host transfer term (est_count_bytes ×
    # XFER_OPS_PER_BYTE ≈ 264 ops/candidate), which dominates at T=W=1
    ctl = _calibrate_counts(_fresh_controller(), a=0.0, b=1e-6)
    assert ctl.should_speculate(10**6)       # no join cost yet: permissive
    ctl.observe_spec(1.0)
    assert ctl.should_speculate(10**6)       # ~265 s count ≫ 0.25 s threshold
    assert not ctl.should_speculate(10**2)   # ~0.027 s count: no window
    assert ctl.decisions[-1].site == "speculate"


def test_choose_fusion_uncalibrated_then_budgeted():
    ctl = _fresh_controller()
    assert ctl.choose_fusion(work_per_unit=1e3, queued=8, max_fuse=16) is None
    for f in (1, 2, 4, 8):                   # exact affine dispatch timings
        ctl.observe_serve(1e3, f, 0.01 + 1e-6 * 1e3 * f)
    # no budget: fuse everything that is queued (bounded by max_fuse)
    assert ctl.choose_fusion(work_per_unit=1e3, queued=8, max_fuse=16) == 8
    assert ctl.choose_fusion(work_per_unit=1e3, queued=8, max_fuse=4) == 4
    # budget 12.5 ms fits a + b·1e3·f for f ≤ 2
    got = ctl.choose_fusion(work_per_unit=1e3, queued=8, max_fuse=16,
                            latency_budget_s=0.0125)
    assert got == 2
    # a budget nothing meets degrades to per-unit dispatch
    assert ctl.choose_fusion(work_per_unit=1e3, queued=8, max_fuse=16,
                             latency_budget_s=1e-9) == 1
    assert ctl.decisions[-1].site == "rule_serve_fusion"


def test_decision_ring_is_capped():
    from repro.costmodel.controller import MAX_DECISIONS, Decision
    ctl = _fresh_controller()
    for i in range(MAX_DECISIONS + 10):
        ctl._record(Decision("pass_width", "k", {}, i))
    assert len(ctl.decisions) == MAX_DECISIONS
    assert ctl.decisions[-1].chosen == MAX_DECISIONS + 9


# ---------------------------------------------------------------------------
# Integration: StreamMiner growing-window prediction + autotune key migration
# ---------------------------------------------------------------------------

def _toy_txns(n, seed=0, n_items=12):
    rng = np.random.default_rng(seed)
    base = rng.random((3, n_items)) < 0.5
    out = []
    for _ in range(n):
        pat = base[rng.integers(3)]
        row = np.where(rng.random(n_items) < 0.85, pat,
                       rng.random(n_items) < 0.1)
        out.append(np.nonzero(row)[0].tolist() or [0])
    return out


def test_stream_remine_prediction_grows_with_window():
    """Regression for the cold-start freeze: after one small-window re-mine
    the predicted cost must keep scaling with the *current* window size."""
    from repro.stream import StreamMiner
    m = StreamMiner(12, 0.3, capacity=128, staleness_factor=1e9,
                    refresh_rules=False, autotune=False,
                    controller=_fresh_controller())
    m.push(_toy_txns(16, seed=3))
    assert m.n_remines >= 1
    p_small = m._predicted_remine_seconds()
    assert p_small == pytest.approx(
        m.controller.predict_remine(m.window.size))
    assert m.controller.predict_remine(8 * m.window.size) > p_small


def test_autotune_legacy_key_migrated_without_resweep(tmp_path, monkeypatch):
    import repro.kernels.autotune as at
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    monkeypatch.setattr(at, "_memory_cache", {})

    def boom(*a, **kw):
        raise AssertionError("migration must not re-sweep")
    monkeypatch.setattr(at, "time_once", boom)

    shape = "vertical/C512/T256/W1/k2"
    legacy_cfg = {"block": 512}
    (tmp_path / "at.json").write_text(json.dumps({f"cpu/{shape}": legacy_cfg}))
    got = at.tuned_blocks("vertical", C=300, T=200, W=1, kmax=2)
    assert got == legacy_cfg
    disk = json.load(open(tmp_path / "at.json"))
    assert disk == {f"{device_key()}/{shape}": legacy_cfg}
