"""Per-arch smoke tests (reduced configs, the assignment requirement) +
numerical parity and SSD correctness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.ssm import ssd_chunked, ssd_reference


def _batch(cfg, B=2, S=16):
    batch = {"tokens": jnp.full((B, S), 3, jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["vision_embeds"] = jnp.full(
            (B, cfg.n_frontend_tokens, cfg.d_model), 0.1, jnp.bfloat16)
    if cfg.frontend == "audio_stub":
        batch["frame_embeds"] = jnp.full(
            (B, cfg.enc_seq, cfg.d_model), 0.1, jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_smoke_forward_and_train_step(arch):
    """One forward + one gradient step on CPU: finite loss, finite grads."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == () and jnp.isfinite(loss), arch
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert jnp.isfinite(leaf.astype(jnp.float32)).all(), arch


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_smoke_decode_step_shapes(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    caches = model.empty_caches(B, 32)
    logits, new_caches = jax.jit(model.decode_step)(
        params, caches, jnp.full((B, 1), 5, jnp.int32), jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, cfg.vocab_padded)
    assert jnp.isfinite(logits[:, :cfg.vocab_size]).all()
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen3-14b", "codeqwen1.5-7b",
                                  "mamba2-370m", "whisper-small"])
def test_prefill_decode_parity(arch):
    """prefill(S) + decode steps == prefill(S+extra) at the last position."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S, extra = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + extra), 0,
                              cfg.vocab_size)
    batch_full = dict(_batch(cfg, B, S + extra), tokens=toks)
    batch_pre = dict(_batch(cfg, B, S), tokens=toks[:, :S])
    logits_full, _ = model.prefill(params, batch_full, cache_len=S + extra)
    cur, caches = model.prefill(params, batch_pre, cache_len=S + extra)
    for t in range(extra):
        cur, caches = model.decode_step(params, caches, toks[:, S + t][:, None],
                                        jnp.full((B,), S + t, jnp.int32))
    err = float(jnp.max(jnp.abs(cur - logits_full)))
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    assert err / scale < 0.05, (arch, err, scale)


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "granite-moe-3b-a800m",
                                  "jamba-v0.1-52b"])
def test_moe_parity_high_capacity(arch):
    """With no-drop capacity, routed prefill == dense decode path."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S, extra = 2, 12, 2
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + extra), 0,
                              cfg.vocab_size)
    logits_full, _ = model.prefill(params, {"tokens": toks}, cache_len=S + extra)
    cur, caches = model.prefill(params, {"tokens": toks[:, :S]},
                                cache_len=S + extra)
    for t in range(extra):
        cur, caches = model.decode_step(params, caches, toks[:, S + t][:, None],
                                        jnp.full((B,), S + t, jnp.int32))
    err = float(jnp.max(jnp.abs(cur - logits_full)))
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    assert err / scale < 0.05, arch


@pytest.mark.parametrize("chunk", [8, 16, 64, 13])
def test_ssd_chunked_vs_reference(chunk):
    rng = np.random.default_rng(0)
    B, S, H, P, N = 2, 64, 3, 8, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(1, 8, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    y_ref = ssd_reference(x, dt, A, Bm, Cm, D)
    y, h = ssd_chunked(x, dt, A, Bm, Cm, D, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_state_continuation():
    """h_final from chunk 1 feeds chunk 2 == single full pass."""
    rng = np.random.default_rng(1)
    B, S, H, P, N = 1, 32, 2, 4, 8
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    x, Bm, Cm = mk(B, S, H, P), mk(B, S, N), mk(B, S, N)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(1, 4, (H,)), jnp.float32)
    D = mk(H)
    y_full, h_full = ssd_chunked(x, dt, A, Bm, Cm, D, 8)
    y1, h1 = ssd_chunked(x[:, :16], dt[:, :16], A, Bm[:, :16], Cm[:, :16], D, 8)
    y2, h2 = ssd_chunked(x[:, 16:], dt[:, 16:], A, Bm[:, 16:], Cm[:, 16:], D, 8,
                         h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)


def test_padded_heads_inactive():
    """Group-padded q heads must not affect outputs (masked everywhere)."""
    cfg = get_config("qwen3-14b", smoke=True)
    assert cfg.padded_heads == cfg.n_heads  # smoke config is unpadded
    full = get_config("qwen3-14b")
    assert full.padded_heads == 48 and full.n_heads == 40
    g = get_config("granite-moe-3b-a800m")
    assert g.padded_heads == 32 and g.n_heads == 24


def test_param_count_sane():
    """Analytic parameter counts are in the right ballpark for known models."""
    approx = {
        "smollm-135m": (0.10e9, 0.25e9),
        "qwen3-14b": (12e9, 17e9),
        # this framework uses gated (SwiGLU) MLPs uniformly; starcoder2's
        # published 15B uses a 2-matrix GELU MLP, so ours lands ≈21B
        "starcoder2-15b": (13e9, 23e9),
        "codeqwen1.5-7b": (6e9, 9e9),
        "internvl2-76b": (60e9, 80e9),
        "mamba2-370m": (0.3e9, 0.5e9),
        "qwen3-moe-30b-a3b": (25e9, 34e9),
        "jamba-v0.1-52b": (45e9, 60e9),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
