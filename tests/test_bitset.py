"""Unit + property tests for the bit-packed itemset algebra."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skip without it
from hypothesis import given, settings, strategies as st

from repro.core.bitset import (MaskIndex, hash_rows, highest_bit_index,
                               lowest_bit_index, n_words, pack_itemsets,
                               popcount_rows, singleton_masks, unpack_itemsets)

itemsets_strategy = st.lists(
    st.lists(st.integers(0, 90), min_size=0, max_size=12).map(lambda x: sorted(set(x))),
    min_size=1, max_size=40)


@given(itemsets_strategy)
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(itemsets):
    masks = pack_itemsets(itemsets, 91)
    assert masks.shape == (len(itemsets), n_words(91))
    assert unpack_itemsets(masks) == [tuple(t) for t in itemsets]


@given(itemsets_strategy)
@settings(max_examples=50, deadline=None)
def test_popcount_matches_len(itemsets):
    masks = pack_itemsets(itemsets, 91)
    assert popcount_rows(masks).tolist() == [len(t) for t in itemsets]


@given(itemsets_strategy)
@settings(max_examples=30, deadline=None)
def test_hi_lo_bits(itemsets):
    masks = pack_itemsets(itemsets, 91)
    hi = highest_bit_index(masks)
    lo = lowest_bit_index(masks)
    for i, t in enumerate(itemsets):
        if t:
            assert hi[i] == max(t) and lo[i] == min(t)
        else:
            assert hi[i] == -1 and lo[i] > 91


def test_singleton_masks():
    s = singleton_masks(70)
    assert popcount_rows(s).tolist() == [1] * 70
    assert unpack_itemsets(s) == [(i,) for i in range(70)]


@given(itemsets_strategy, itemsets_strategy)
@settings(max_examples=30, deadline=None)
def test_mask_index_membership(base, queries):
    bm = pack_itemsets(base, 91)
    qm = pack_itemsets(queries, 91)
    idx = MaskIndex(bm)
    got = idx.contains(qm)
    base_set = {tuple(t) for t in base}
    want = np.array([tuple(t) in base_set for t in queries])
    assert (got == want).all()


def test_hash_distinct():
    rng = np.random.default_rng(0)
    masks = rng.integers(0, 2**32, (5000, 3), dtype=np.uint32)
    masks = np.unique(masks, axis=0)
    h = hash_rows(masks)
    assert len(np.unique(h)) == len(masks)  # no collisions at this scale
