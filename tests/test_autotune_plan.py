"""Cross-family autotune plans (DESIGN.md §10): winner selection + caching.

The fake-timer tests script per-family wall times into ``time_once`` so the
joint sweep's behaviour is checked deterministically — in particular the
regression this PR fixes: a tuned single-family winner ("vertical" at C=256)
that loses to the plain jnp baseline by ~43× must never be picked once the
baseline is cross-checked in the same sweep.
"""

import json

import numpy as np
import pytest

import repro.kernels.autotune as at
from repro.costmodel.measure import device_key


def _fresh(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    monkeypatch.setattr(at, "_memory_cache", {})
    # the plan sweep prices candidates off the shared cost model; a
    # calibrated per-machine cache (~/.cache/repro/costmodel.json) could
    # prune scripted families, so isolate it too
    import repro.costmodel.model as cm
    monkeypatch.setenv("REPRO_COSTMODEL_CACHE", str(tmp_path / "cm.json"))
    monkeypatch.setattr(cm, "_default", None)


def _script_times(monkeypatch, times_us):
    """Make every family run at its scripted time (µs), configs tie."""
    def fake_runner(impl, C, T, W, kmax, **kw):
        return lambda cfg, impl=impl: impl
    def fake_time_once(marker):
        return times_us[marker] * 1e-6
    monkeypatch.setattr(at, "_candidate_runner", fake_runner)
    monkeypatch.setattr(at, "time_once", fake_time_once)


def test_plan_disabled_returns_none(monkeypatch, tmp_path):
    _fresh(monkeypatch, tmp_path)
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    assert at.tuned_plan("count", C=256, T=8124, W=4) is None


def test_plan_unknown_kind_raises(monkeypatch, tmp_path):
    _fresh(monkeypatch, tmp_path)
    with pytest.raises(ValueError):
        at.tuned_plan("frobnicate", C=1, T=1)


def test_plan_baseline_beats_tuned_vertical_own_goal(monkeypatch, tmp_path):
    """The recorded C=256 own-goal: vertical 107.7ms vs jnp 2.5ms — the joint
    sweep must pick jnp even though vertical was the tuned layout winner."""
    _fresh(monkeypatch, tmp_path)
    _script_times(monkeypatch, {
        "jnp": 2509.0, "matmul": 6000.0,
        "vertical": 107708.7, "vertical_matmul": 15000.0})
    plan = at.tuned_plan("count", C=256, T=8124, W=4, kmax=23, backend="cpu")
    assert plan["impl"] == "jnp" and plan["family"] == "jnp"
    assert "jnp" in plan["timed_us"]            # baseline always cross-checked
    # winner never slower than any timed family
    assert plan["timed_us"][plan["family"]] == min(plan["timed_us"].values())


@pytest.mark.parametrize("kind,times,want", [
    ("count", {"jnp": 90.0, "matmul": 20.0, "vertical": 400.0,
               "vertical_matmul": 100.0}, "matmul"),
    ("delta", {"delta_jnp": 50.0, "delta_matmul": 10.0}, "matmul"),
    ("rules", {"rules_jnp": 30.0, "rules_matmul": 5.0}, "matmul"),
])
def test_plan_picks_fastest_family(monkeypatch, tmp_path, kind, times, want):
    _fresh(monkeypatch, tmp_path)
    _script_times(monkeypatch, times)
    plan = at.tuned_plan(kind, C=128, T=1024, W=2, backend="cpu")
    assert plan["impl"] == want
    assert set(plan["timed_us"]) == set(times)


def test_plan_cached_no_resweep(monkeypatch, tmp_path):
    _fresh(monkeypatch, tmp_path)
    _script_times(monkeypatch, {"delta_jnp": 5.0, "delta_matmul": 50.0})
    first = at.tuned_plan("delta", C=64, T=512, W=1, backend="cpu")
    assert first["impl"] == "jnp"
    disk = json.load(open(tmp_path / "at.json"))
    plan_keys = [k for k in disk if "/plan/delta/" in k]
    assert len(plan_keys) == 1 and plan_keys[0].startswith(device_key("cpu"))

    def boom(*a, **kw):
        raise AssertionError("cached plan must not re-sweep")
    monkeypatch.setattr(at, "time_once", boom)
    again = at.tuned_plan("delta", C=64, T=512, W=1, backend="cpu")
    assert again["impl"] == first["impl"]
    # and a fresh process (cold memory cache) reads the disk entry
    monkeypatch.setattr(at, "_memory_cache", {})
    cold = at.tuned_plan("delta", C=64, T=512, W=1, backend="cpu")
    assert cold["impl"] == first["impl"]


def test_plan_survives_family_failures(monkeypatch, tmp_path):
    """A family whose runner raises is skipped, not fatal."""
    _fresh(monkeypatch, tmp_path)
    def fake_runner(impl, C, T, W, kmax, **kw):
        return lambda cfg, impl=impl: impl
    def flaky(marker):
        if marker != "delta_matmul":
            raise RuntimeError("no lowering")
        return 1e-3
    monkeypatch.setattr(at, "_candidate_runner", fake_runner)
    monkeypatch.setattr(at, "time_once", flaky)
    plan = at.tuned_plan("delta", C=64, T=512, W=1, backend="cpu")
    assert plan["family"] == "delta_matmul"


def test_runtime_auto_impl_follows_plan(monkeypatch, tmp_path):
    """MapReduceRuntime(impl='auto') adopts the plan winner in scatter_db."""
    from repro.core.mapreduce import IMPLS, MapReduceRuntime
    _fresh(monkeypatch, tmp_path)
    _script_times(monkeypatch, {
        "jnp": 500.0, "matmul": 5.0, "vertical": 900.0,
        "vertical_matmul": 700.0})
    rt = MapReduceRuntime(impl="auto")
    assert rt._auto_impl
    rng = np.random.default_rng(0)
    masks = rng.integers(0, 2**32, (200, 1), dtype=np.uint32)
    rt.scatter_db(masks, n_items=20)
    assert rt.impl == "matmul" and rt.impl in IMPLS


@pytest.mark.slow
def test_plan_real_sweep_never_loses_to_single_family(monkeypatch, tmp_path):
    """Real timings: the joint winner is ≤ every single family it timed."""
    _fresh(monkeypatch, tmp_path)
    plan = at.tuned_plan("count", C=256, T=2048, W=4, kmax=8)
    assert plan is not None and plan["timed_us"]
    best = min(plan["timed_us"].values())
    assert plan["timed_us"][plan["family"]] == best
    assert "jnp" in plan["timed_us"]
