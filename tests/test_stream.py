"""Streaming subsystem: window ring-buffer semantics, delta-count kernel
bit-exactness, incremental-vs-scratch equivalence (the tentpole property),
re-mine triggers and atomic rule swapping."""

import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import generate_ruleset, mine
from repro.core.bitset import pack_itemsets
from repro.core.mapreduce import MapReduceRuntime
from repro.kernels import delta_count, support_count
from repro.kernels.delta_count import (build_slab, delta_count_jnp,
                                       delta_count_pallas)
from repro.stream import StreamMiner, TransactionWindow
from repro.stream.tables import levels_equal

N_ITEMS = 12
MIN_SUP = 0.3


def toy_txns(n, seed=0, n_items=N_ITEMS, drop=None):
    """Patterned random baskets (same shape as the rules-engine fixture)."""
    rng = np.random.default_rng(seed)
    base = rng.random((3, n_items)) < 0.5
    out = []
    for _ in range(n):
        pat = base[rng.integers(3)]
        row = np.where(rng.random(n_items) < 0.85, pat,
                       rng.random(n_items) < 0.1)
        t = np.nonzero(row)[0].tolist() or [0]
        if drop is not None:
            t = [i for i in t if i not in drop] or [0]
        out.append(t)
    return out


# ---------------------------------------------------------------------------
# TransactionWindow
# ---------------------------------------------------------------------------

def test_window_pow2_capacity_and_fifo():
    w = TransactionWindow(N_ITEMS, capacity=100)      # buckets up to 128
    assert w.capacity == 128
    txns = toy_txns(140, seed=1)
    d1 = w.append(txns[:100])
    assert d1.n_added == 100 and d1.n_evicted == 0 and w.size == 100
    d2 = w.append(txns[100:140])                      # overflows by 12
    assert d2.n_added == 40 and d2.n_evicted == 12 and w.size == 128
    # FIFO: the evicted rows are exactly the 12 oldest appended
    np.testing.assert_array_equal(d2.evicted,
                                  pack_itemsets(txns[:12], N_ITEMS))
    np.testing.assert_array_equal(w.contents(),
                                  pack_itemsets(txns[12:140], N_ITEMS))


def test_window_oversized_batch_keeps_newest():
    w = TransactionWindow(N_ITEMS, capacity=64)
    w.append(toy_txns(10, seed=2))
    big = toy_txns(80, seed=3)
    d = w.append(big)
    assert w.size == 64 and d.n_added == 64
    assert d.n_evicted == 10                          # all previous rows left
    np.testing.assert_array_equal(w.contents(),
                                  pack_itemsets(big[-64:], N_ITEMS))


def test_window_landmark_grows():
    w = TransactionWindow(N_ITEMS, capacity=64, mode="landmark")
    txns = toy_txns(200, seed=4)
    for i in range(0, 200, 50):
        d = w.append(txns[i:i + 50])
        assert d.n_evicted == 0
    assert w.size == 200 and w.capacity == 256        # doubled as needed
    np.testing.assert_array_equal(w.contents(), pack_itemsets(txns, N_ITEMS))


def test_window_evict_and_device_mirror():
    w = TransactionWindow(N_ITEMS, capacity=64)
    txns = toy_txns(90, seed=5)
    w.append(txns[:60])
    d = w.evict(20)
    np.testing.assert_array_equal(d.evicted, pack_itemsets(txns[:20], N_ITEMS))
    w.append(txns[60:90])                             # wraps the ring
    assert w.size == 64                               # 40 + 30 − 6 evicted
    # the device ring holds exactly the live rows (vacant slots zero)
    host = np.zeros((w.capacity, w.W), np.uint32)
    slots = (w._start + np.arange(w.size)) % w.capacity
    host[slots] = w.contents()
    np.testing.assert_array_equal(np.asarray(w.device_masks()), host)
    # evicting everything empties cleanly
    w.evict(w.size)
    assert w.size == 0 and w.contents().shape == (0, w.W)
    assert not np.asarray(w.device_masks()).any()


# ---------------------------------------------------------------------------
# Delta counting kernel
# ---------------------------------------------------------------------------

def test_delta_count_matches_signed_support():
    rng = np.random.default_rng(0)
    cands = rng.integers(0, 2**16, (37, 2), dtype=np.uint32)
    cands[5] = 0                                      # empty candidate row
    added = rng.integers(0, 2**16, (23, 2), dtype=np.uint32)
    evicted = rng.integers(0, 2**16, (11, 2), dtype=np.uint32)
    want = (np.asarray(support_count(cands, added, impl="jnp"))
            - np.asarray(support_count(cands, evicted, impl="jnp")))
    got = delta_count(cands, added, evicted, impl="jnp")
    np.testing.assert_array_equal(got, want)
    # empty slabs → all-zero delta, either side
    zero = np.zeros((0, 2), np.uint32)
    assert not delta_count(cands, zero, zero, impl="jnp").any()
    np.testing.assert_array_equal(
        delta_count(cands, added, zero, impl="jnp"),
        np.asarray(support_count(cands, added, impl="jnp")))


def test_delta_count_pallas_interpret_bit_exact():
    rng = np.random.default_rng(1)
    cands = rng.integers(0, 2**32, (64, 3), dtype=np.uint32)
    slab, signs = build_slab(rng.integers(0, 2**32, (17, 3), dtype=np.uint32),
                             rng.integers(0, 2**32, (9, 3), dtype=np.uint32))
    ref = np.asarray(delta_count_jnp(cands, slab, signs, block=8))
    pal = np.asarray(delta_count_pallas(cands, slab, signs, bc=16, bt=8,
                                        interpret=True))
    np.testing.assert_array_equal(ref, pal)
    got = delta_count(cands, slab[:17], slab[17:26], impl="pallas_interpret")
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# Incremental ≡ from-scratch (the tentpole property)
# ---------------------------------------------------------------------------

def assert_state_exact(miner):
    """Frequent itemsets, supports AND the published RuleSet must equal a
    from-scratch mine of the current window, bit-exactly."""
    if miner.window.size == 0:
        assert miner.levels == {} and miner.engine.n_rules == 0
        return
    scratch = mine(db_masks=miner.window.contents(), n_items=miner.n_items,
                   min_sup=miner.min_sup, algorithm=miner.algorithm,
                   runtime=miner.runtime)
    assert levels_equal(miner.levels, scratch.levels)
    want = generate_ruleset(scratch, miner.min_confidence)
    got = miner.engine.rules
    for field in ("ante_masks", "cons_masks", "union_counts", "ante_counts",
                  "cons_counts"):
        np.testing.assert_array_equal(getattr(got, field),
                                      getattr(want, field), err_msg=field)


def run_sequence(ops, mode="sliding", capacity=64, seed=0):
    miner = StreamMiner(N_ITEMS, MIN_SUP, capacity=capacity, mode=mode,
                        min_confidence=0.6)
    paths = []
    for kind, payload in ops:
        rec = miner.push(payload) if kind == "append" else miner.evict(payload)
        paths.append(rec.path)
        assert_state_exact(miner)
    return miner, paths


def random_ops(seed, n_ops=8, max_batch=12):
    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(n_ops):
        if rng.random() < 0.7:
            ops.append(("append",
                        toy_txns(int(rng.integers(1, max_batch)),
                                 seed=int(rng.integers(1 << 20)))))
        else:
            ops.append(("evict", int(rng.integers(1, 16))))
    return ops


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("mode", ["sliding", "landmark"])
def test_incremental_equals_scratch_random_sequences(seed, mode):
    miner, paths = run_sequence(random_ops(seed), mode=mode)
    assert len(miner.updates) == len(paths)


def test_delta_path_actually_taken_and_exact():
    """A stationary stream must settle onto the O(delta) path (not re-mine
    every step) while staying exact — guards against a trivially-correct
    implementation that always re-mines."""
    txns = toy_txns(200, seed=7)
    miner = StreamMiner(N_ITEMS, MIN_SUP, capacity=64)
    miner.push(txns[:64])
    paths = [miner.push(txns[64 + 4 * i:64 + 4 * (i + 1)]).path
             for i in range(8)]
    assert "delta" in paths
    assert_state_exact(miner)


def test_structural_drift_forces_remine():
    """Shifting the distribution hard enough must fall back to a full
    re-mine (untracked candidates), and stay exact through it."""
    miner = StreamMiner(N_ITEMS, MIN_SUP, capacity=64)
    miner.push(toy_txns(64, seed=8))
    n0 = miner.n_remines
    # flood with wide baskets: many new itemsets go frequent at once
    wide = [[i for i in range(N_ITEMS) if i % 2 == 0] for _ in range(48)]
    miner.push(wide)
    miner.push(wide)
    assert miner.n_remines > n0
    assert_state_exact(miner)


def test_staleness_trigger_remines():
    miner = StreamMiner(N_ITEMS, MIN_SUP, capacity=64,
                        staleness_factor=1e-9)      # hair trigger
    miner.push(toy_txns(64, seed=9))
    rec = miner.push(toy_txns(4, seed=10))
    assert rec.path in ("remine_staleness", "remine_structural")
    assert_state_exact(miner)


def test_empty_window_round_trip():
    miner = StreamMiner(N_ITEMS, MIN_SUP, capacity=64)
    txns = toy_txns(32, seed=11)
    miner.push(txns)
    rec = miner.evict(32)
    assert rec.path == "empty" and miner.levels == {}
    assert miner.query([[0, 1]]) == [[]]
    rec = miner.push(txns[:16])                     # refills → fresh re-mine
    assert rec.path == "remine"
    assert_state_exact(miner)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=8, deadline=None)
@given(st.lists(
    st.one_of(
        st.tuples(st.just("append"),
                  st.lists(st.lists(st.integers(0, N_ITEMS - 1),
                                    min_size=1, max_size=6),
                           min_size=1, max_size=10)),
        st.tuples(st.just("evict"), st.integers(1, 12))),
    min_size=1, max_size=6))
def test_property_incremental_equals_scratch(ops):
    """For ANY sequence of append/evict micro-batches, incremental state ==
    from-scratch mine of the window contents, at every step."""
    run_sequence(ops, capacity=64)


# ---------------------------------------------------------------------------
# Live rule refresh / atomic swap
# ---------------------------------------------------------------------------

def test_swap_rules_is_atomic_and_live():
    txns = toy_txns(120, seed=12)
    res = mine(txns[:120], n_items=N_ITEMS, min_sup=MIN_SUP)
    rules_a = generate_ruleset(res, min_confidence=0.6)
    res_b = mine(txns[:60], n_items=N_ITEMS, min_sup=0.5)
    rules_b = generate_ruleset(res_b, min_confidence=0.6)
    assert len(rules_a) != len(rules_b)

    from repro.serving import RuleServeEngine
    eng = RuleServeEngine(rules_a, impl="jnp")
    baskets = [sorted(set(t[:-1])) or [0] for t in txns[:10]]
    before = eng.query(baskets)
    eng.swap_rules(rules_b, warm_to=16)
    assert eng.n_rules == len(rules_b)
    after = eng.query(baskets)
    # post-swap answers match a fresh engine on the new rules (complete
    # table, no torn state), and the old results object is untouched
    fresh = RuleServeEngine(rules_b, impl="jnp").query(baskets)
    assert after == fresh
    assert before == RuleServeEngine(rules_a, impl="jnp").query(baskets)


def test_stream_refresh_serves_current_rules():
    txns = toy_txns(160, seed=13)
    miner = StreamMiner(N_ITEMS, MIN_SUP, capacity=64, min_confidence=0.6)
    miner.push(txns[:64])
    baskets = [sorted(set(t[:-1])) or [0] for t in txns[:5]]
    for i in range(3):
        miner.push(txns[64 + 8 * i:64 + 8 * (i + 1)])
        want = generate_ruleset(
            mine(db_masks=miner.window.contents(), n_items=N_ITEMS,
                 min_sup=MIN_SUP), miner.min_confidence)
        from repro.serving import RuleServeEngine
        fresh = RuleServeEngine(want, impl="jnp").query(baskets)
        assert miner.query(baskets) == fresh


def test_update_records_are_coherent():
    miner = StreamMiner(N_ITEMS, MIN_SUP, capacity=64)
    miner.push(toy_txns(64, seed=14))
    miner.push(toy_txns(4, seed=15))
    recs = miner.updates
    assert [r.seq for r in recs] == list(range(len(recs)))
    assert recs[0].path == "remine" and recs[0].remine_seconds > 0
    assert all(r.window_size <= 64 for r in recs)
    assert all(r.n_rules == 0 or r.n_frequent > 0 for r in recs)
