"""Kernel impl families vs pure-jnp oracles: shape/dtype sweeps + properties.

Every kernel family (DESIGN.md §10) is checked bit-exact against its popcount
oracle: the bit-plane int8 matmul twins (``matmul``/``matmul_pallas``) must
agree with the ``jnp``/``pallas`` forms on ragged tails, W>1, empty candidates
and zero padding.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.bitset import (jpack_bits, junpack_bits, pack_itemsets,
                               vertical_pack)
from repro.kernels import support_count, support_count_ref
from repro.kernels.delta_count import (delta_count_jnp, delta_count_matmul,
                                       delta_count_matmul_pallas)
from repro.kernels.rule_match import (rule_scores_jnp, rule_scores_matmul,
                                      rule_scores_matmul_pallas)
from repro.kernels.support_count import (support_count_matmul,
                                         support_count_pallas)
from repro.kernels.vertical_count import (vertical_count_jnp,
                                          vertical_count_matmul,
                                          vertical_count_matmul_pallas)


@pytest.mark.parametrize("C,T,W", [
    (1, 1, 1), (3, 5, 1), (17, 33, 2), (64, 128, 3),
    (256, 512, 6), (300, 700, 8), (256, 512, 1),
])
def test_pallas_matches_ref_shapes(C, T, W):
    rng = np.random.default_rng(C * 1000 + T + W)
    cands = rng.integers(0, 2**32, (C, W), dtype=np.uint32)
    txns = rng.integers(0, 2**32, (T, W), dtype=np.uint32)
    ref = np.asarray(support_count_ref(jnp.asarray(cands), jnp.asarray(txns)))
    pal = np.asarray(support_count(cands, txns, impl="pallas"))
    jn = np.asarray(support_count(cands, txns, impl="jnp"))
    np.testing.assert_array_equal(pal, ref)
    np.testing.assert_array_equal(jn, ref)


@pytest.mark.parametrize("bc,bt", [(8, 16), (128, 256), (256, 512)])
def test_pallas_block_shapes(bc, bt):
    rng = np.random.default_rng(bc + bt)
    C, T, W = bc * 2, bt * 3, 4
    cands = rng.integers(0, 2**32, (C, W), dtype=np.uint32)
    txns = rng.integers(0, 2**32, (T, W), dtype=np.uint32)
    ref = np.asarray(support_count_ref(jnp.asarray(cands), jnp.asarray(txns)))
    pal = np.asarray(support_count_pallas(
        jnp.asarray(cands), jnp.asarray(txns), bc=bc, bt=bt, interpret=True))
    np.testing.assert_array_equal(pal, ref)


@given(st.lists(st.lists(st.integers(0, 60), min_size=0, max_size=10)
                .map(lambda x: sorted(set(x))), min_size=1, max_size=20),
       st.lists(st.lists(st.integers(0, 60), min_size=0, max_size=20)
                .map(lambda x: sorted(set(x))), min_size=1, max_size=30))
@settings(max_examples=25, deadline=None)
def test_support_count_is_subset_count(cand_sets, txn_sets):
    """Property: count == #transactions containing the candidate."""
    cands = pack_itemsets(cand_sets, 61)
    txns = pack_itemsets(txn_sets, 61)
    got = np.asarray(support_count(cands, txns, impl="pallas"))
    for i, cs in enumerate(cand_sets):
        want = sum(1 for t in txn_sets if set(cs) <= set(t))
        assert got[i] == want


def test_zero_padding_safety():
    """Zero txn rows never match non-empty candidates; zero candidates match all."""
    cands = pack_itemsets([[0], []], 32)
    txns = np.concatenate([pack_itemsets([[0], [1]], 32),
                           np.zeros((5, 1), np.uint32)])
    for impl in ("pallas", "matmul", "matmul_pallas"):
        got = np.asarray(support_count(cands, txns, impl=impl))
        assert got[0] == 1      # [0] ⊆ only the first txn
        assert got[1] == 7      # empty set ⊆ everything incl. zero rows


# ---------------------------------------------------------------------------
# bit-plane helpers and the matmul twins (DESIGN.md §10)
# ---------------------------------------------------------------------------

def test_bitplane_pack_roundtrip():
    rng = np.random.default_rng(0)
    words = rng.integers(0, 2**32, (13, 3), dtype=np.uint32)
    planes = junpack_bits(jnp.asarray(words))
    assert planes.shape == (13, 96) and planes.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(jpack_bits(planes)), words)
    # little bit-order: column w*32+b holds bit b of word w
    one = np.zeros((1, 2), np.uint32)
    one[0, 1] = 1 << 7
    col = np.asarray(junpack_bits(jnp.asarray(one)))[0]
    assert col[32 + 7] == 1 and col.sum() == 1


@pytest.mark.parametrize("C,T,W", [(1, 1, 1), (17, 33, 2), (300, 700, 8)])
@pytest.mark.parametrize("impl", ["matmul", "matmul_pallas"])
def test_matmul_impls_match_ref(C, T, W, impl):
    rng = np.random.default_rng(C + T + W)
    cands = rng.integers(0, 2**32, (C, W), dtype=np.uint32)
    cands[0] = 0                     # empty candidate: matches everything
    txns = rng.integers(0, 2**32, (T, W), dtype=np.uint32)
    ref = np.asarray(support_count_ref(jnp.asarray(cands), jnp.asarray(txns)))
    got = np.asarray(support_count(cands, txns, impl=impl))
    np.testing.assert_array_equal(got, ref)


def test_support_count_matmul_blocking_invariance():
    rng = np.random.default_rng(3)
    cands = rng.integers(0, 2**32, (37, 2), dtype=np.uint32)
    txns = rng.integers(0, 2**32, (101, 2), dtype=np.uint32)
    ref = support_count_matmul(jnp.asarray(cands), jnp.asarray(txns),
                               block=101)
    for blk in (1, 7, 64, 4096):
        got = support_count_matmul(jnp.asarray(cands), jnp.asarray(txns),
                                   block=blk)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def _random_vertical(rng, n_items=37, n=101, kmax=5, C=23):
    db = pack_itemsets(
        [sorted(rng.choice(n_items, rng.integers(0, 8), replace=False))
         for _ in range(n)], n_items)
    vdb = vertical_pack(db, n_items)
    idx = np.full((C, kmax), n_items, np.int32)
    for i in range(C):
        k = rng.integers(0, kmax + 1)
        idx[i, :k] = rng.choice(n_items, k, replace=False)
    idx[C // 2, :] = n_items         # all-padding candidate (empty set)
    return vdb, idx


def test_vertical_matmul_matches_oracle():
    rng = np.random.default_rng(11)
    vdb, idx = _random_vertical(rng)
    ref = np.asarray(vertical_count_jnp(jnp.asarray(vdb), jnp.asarray(idx)))
    mm = np.asarray(vertical_count_matmul(jnp.asarray(vdb), jnp.asarray(idx),
                                          block=8))
    np.testing.assert_array_equal(mm, ref)
    mp = np.asarray(vertical_count_matmul_pallas(
        jnp.asarray(vdb), jnp.asarray(idx), bc=8, bt=64, interpret=True))
    np.testing.assert_array_equal(mp, ref)


def test_vertical_matmul_duplicate_slots():
    """Repeated item ids in a candidate row must stay AND-idempotent."""
    rng = np.random.default_rng(12)
    vdb, idx = _random_vertical(rng)
    idx[1, 1] = idx[1, 0]
    ref = np.asarray(vertical_count_jnp(jnp.asarray(vdb), jnp.asarray(idx)))
    mm = np.asarray(vertical_count_matmul(jnp.asarray(vdb), jnp.asarray(idx)))
    np.testing.assert_array_equal(mm, ref)


def test_delta_matmul_matches_oracle():
    rng = np.random.default_rng(21)
    C, T, W = 19, 26, 2
    cands = rng.integers(0, 2**32, (C, W), dtype=np.uint32)
    cands[0] = 0
    slab = rng.integers(0, 2**32, (T, W), dtype=np.uint32)
    slab[4] = 0
    signs = rng.choice(np.array([-1, 0, 1], np.int32), T)
    ref = np.asarray(delta_count_jnp(jnp.asarray(cands), jnp.asarray(slab),
                                     jnp.asarray(signs)))
    mm = np.asarray(delta_count_matmul(jnp.asarray(cands), jnp.asarray(slab),
                                       jnp.asarray(signs), block=8))
    np.testing.assert_array_equal(mm, ref)
    # pallas twin on pre-padded operands (sign-0 padding is a no-op)
    Cp, Tp = 24, 32
    cp = np.concatenate([cands, np.zeros((Cp - C, W), np.uint32)])
    sp = np.concatenate([slab, np.zeros((Tp - T, W), np.uint32)])
    sg = np.concatenate([signs, np.zeros(Tp - T, np.int32)])
    mp = np.asarray(delta_count_matmul_pallas(
        jnp.asarray(cp), jnp.asarray(sp), jnp.asarray(sg),
        bc=8, bt=16, interpret=True))[:C]
    np.testing.assert_array_equal(mp, ref)


@pytest.mark.parametrize("exclude_contained", [True, False])
def test_rule_scores_matmul_matches_oracle(exclude_contained):
    rng = np.random.default_rng(31)
    R, Q, W = 21, 14, 2
    antes = rng.integers(0, 2**32, (R, W), dtype=np.uint32)
    cons = rng.integers(0, 2**32, (R, W), dtype=np.uint32) & ~antes
    antes[2] = 0                     # empty antecedent: fires on every basket
    cons[3] = 0                      # empty consequent: contained everywhere
    scores = rng.random(R).astype(np.float32)
    baskets = rng.integers(0, 2**32, (Q, W), dtype=np.uint32)
    baskets[0] = 0xFFFFFFFF
    args = (jnp.asarray(antes), jnp.asarray(cons), jnp.asarray(scores),
            jnp.asarray(baskets))
    ref = np.asarray(rule_scores_jnp(*args, q_block=4,
                                     exclude_contained=exclude_contained))
    mm = np.asarray(rule_scores_matmul(*args, q_block=4,
                                       exclude_contained=exclude_contained))
    np.testing.assert_array_equal(mm, ref)
    mp = np.asarray(rule_scores_matmul_pallas(
        *args, bq=8, br=16, exclude_contained=exclude_contained,
        interpret=True))
    np.testing.assert_array_equal(mp, ref)


@given(st.lists(st.lists(st.integers(0, 60), min_size=0, max_size=10)
                .map(lambda x: sorted(set(x))), min_size=1, max_size=20),
       st.lists(st.lists(st.integers(0, 60), min_size=0, max_size=20)
                .map(lambda x: sorted(set(x))), min_size=1, max_size=30))
@settings(max_examples=25, deadline=None)
def test_matmul_support_count_is_subset_count(cand_sets, txn_sets):
    """Property: the matmul arm is an exact subset counter too."""
    cands = pack_itemsets(cand_sets, 61)
    txns = pack_itemsets(txn_sets, 61)
    got = np.asarray(support_count(cands, txns, impl="matmul"))
    gotp = np.asarray(support_count(cands, txns, impl="matmul_pallas"))
    for i, cs in enumerate(cand_sets):
        want = sum(1 for t in txn_sets if set(cs) <= set(t))
        assert got[i] == want
        assert gotp[i] == want
