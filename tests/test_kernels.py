"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps + properties."""

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.bitset import pack_itemsets
from repro.kernels import support_count, support_count_ref
from repro.kernels.support_count import support_count_pallas


@pytest.mark.parametrize("C,T,W", [
    (1, 1, 1), (3, 5, 1), (17, 33, 2), (64, 128, 3),
    (256, 512, 6), (300, 700, 8), (256, 512, 1),
])
def test_pallas_matches_ref_shapes(C, T, W):
    rng = np.random.default_rng(C * 1000 + T + W)
    cands = rng.integers(0, 2**32, (C, W), dtype=np.uint32)
    txns = rng.integers(0, 2**32, (T, W), dtype=np.uint32)
    ref = np.asarray(support_count_ref(jnp.asarray(cands), jnp.asarray(txns)))
    pal = np.asarray(support_count(cands, txns, impl="pallas"))
    jn = np.asarray(support_count(cands, txns, impl="jnp"))
    np.testing.assert_array_equal(pal, ref)
    np.testing.assert_array_equal(jn, ref)


@pytest.mark.parametrize("bc,bt", [(8, 16), (128, 256), (256, 512)])
def test_pallas_block_shapes(bc, bt):
    rng = np.random.default_rng(bc + bt)
    C, T, W = bc * 2, bt * 3, 4
    cands = rng.integers(0, 2**32, (C, W), dtype=np.uint32)
    txns = rng.integers(0, 2**32, (T, W), dtype=np.uint32)
    ref = np.asarray(support_count_ref(jnp.asarray(cands), jnp.asarray(txns)))
    pal = np.asarray(support_count_pallas(
        jnp.asarray(cands), jnp.asarray(txns), bc=bc, bt=bt, interpret=True))
    np.testing.assert_array_equal(pal, ref)


@given(st.lists(st.lists(st.integers(0, 60), min_size=0, max_size=10)
                .map(lambda x: sorted(set(x))), min_size=1, max_size=20),
       st.lists(st.lists(st.integers(0, 60), min_size=0, max_size=20)
                .map(lambda x: sorted(set(x))), min_size=1, max_size=30))
@settings(max_examples=25, deadline=None)
def test_support_count_is_subset_count(cand_sets, txn_sets):
    """Property: count == #transactions containing the candidate."""
    cands = pack_itemsets(cand_sets, 61)
    txns = pack_itemsets(txn_sets, 61)
    got = np.asarray(support_count(cands, txns, impl="pallas"))
    for i, cs in enumerate(cand_sets):
        want = sum(1 for t in txn_sets if set(cs) <= set(t))
        assert got[i] == want


def test_zero_padding_safety():
    """Zero txn rows never match non-empty candidates; zero candidates match all."""
    cands = pack_itemsets([[0], []], 32)
    txns = np.concatenate([pack_itemsets([[0], [1]], 32),
                           np.zeros((5, 1), np.uint32)])
    got = np.asarray(support_count(cands, txns, impl="pallas"))
    assert got[0] == 1          # [0] ⊆ only the first txn
    assert got[1] == 7          # empty set ⊆ everything incl. zero rows
