"""Association-rule generation vs brute force (completes the ARM pipeline)."""

from itertools import combinations

import numpy as np
import pytest

from repro.core import mine, sequential_apriori
from repro.core.rules import generate_rules


def brute_rules(levels, n_txns, min_conf):
    """All rules from an oracle level dict {k: {tuple: count}}."""
    sup = {}
    for k, d in levels.items():
        sup.update(d)
    out = set()
    for itemset, cnt in sup.items():
        if len(itemset) < 2:
            continue
        items = set(itemset)
        for r in range(1, len(itemset)):
            for cons in combinations(sorted(items), r):
                ante = tuple(sorted(items - set(cons)))
                if ante not in sup:
                    continue
                conf = cnt / sup[ante]
                if conf + 1e-12 >= min_conf:
                    out.add((ante, tuple(sorted(cons)), round(conf, 9)))
    return out


@pytest.fixture(scope="module")
def mined():
    rng = np.random.default_rng(2)
    base = rng.random((3, 16)) < 0.5
    txns = []
    for _ in range(150):
        pat = base[rng.integers(3)]
        row = np.where(rng.random(16) < 0.85, pat, rng.random(16) < 0.1)
        txns.append(np.nonzero(row)[0].tolist() or [0])
    res = mine(txns, n_items=16, min_sup=0.3, algorithm="optimized_vfpc")
    oracle = sequential_apriori(txns, 0.3)
    return res, oracle


def test_rules_match_bruteforce(mined):
    res, oracle = mined
    got = {(r.antecedent, r.consequent, round(r.confidence, 9))
           for r in generate_rules(res, min_confidence=0.7)}
    want = brute_rules(oracle, res.n_txns, 0.7)
    assert got == want
    assert len(got) > 0


def test_rules_confidence_threshold(mined):
    res, _ = mined
    rules = generate_rules(res, min_confidence=0.9)
    assert all(r.confidence + 1e-12 >= 0.9 for r in rules)
    assert rules == sorted(rules, key=lambda r: (-r.confidence, -r.lift))


def test_rules_support_consistency(mined):
    res, oracle = mined
    for r in generate_rules(res, min_confidence=0.8, max_rules=20):
        union = tuple(sorted(set(r.antecedent) | set(r.consequent)))
        assert oracle[len(union)][union] == round(r.support * res.n_txns)
