"""Association-rule generation vs brute force (completes the ARM pipeline)."""

from itertools import combinations

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import mine, sequential_apriori
from repro.core.bitset import pack_itemsets
from repro.core.drivers import MiningResult
from repro.core.rules import generate_rules, generate_ruleset


def brute_rules(levels, n_txns, min_conf):
    """All rules from an oracle level dict {k: {tuple: count}}."""
    sup = {}
    for k, d in levels.items():
        sup.update(d)
    out = set()
    for itemset, cnt in sup.items():
        if len(itemset) < 2:
            continue
        items = set(itemset)
        for r in range(1, len(itemset)):
            for cons in combinations(sorted(items), r):
                ante = tuple(sorted(items - set(cons)))
                if ante not in sup:
                    continue
                conf = cnt / sup[ante]
                if conf + 1e-12 >= min_conf:
                    out.add((ante, tuple(sorted(cons)), round(conf, 9)))
    return out


@pytest.fixture(scope="module")
def mined():
    rng = np.random.default_rng(2)
    base = rng.random((3, 16)) < 0.5
    txns = []
    for _ in range(150):
        pat = base[rng.integers(3)]
        row = np.where(rng.random(16) < 0.85, pat, rng.random(16) < 0.1)
        txns.append(np.nonzero(row)[0].tolist() or [0])
    res = mine(txns, n_items=16, min_sup=0.3, algorithm="optimized_vfpc")
    oracle = sequential_apriori(txns, 0.3)
    return res, oracle


def test_rules_match_bruteforce(mined):
    res, oracle = mined
    got = {(r.antecedent, r.consequent, round(r.confidence, 9))
           for r in generate_rules(res, min_confidence=0.7)}
    want = brute_rules(oracle, res.n_txns, 0.7)
    assert got == want
    assert len(got) > 0


def test_rules_confidence_threshold(mined):
    res, _ = mined
    rules = generate_rules(res, min_confidence=0.9)
    assert all(r.confidence + 1e-12 >= 0.9 for r in rules)
    assert rules == sorted(rules, key=lambda r: (-r.confidence, -r.lift))


def test_rules_support_consistency(mined):
    res, oracle = mined
    for r in generate_rules(res, min_confidence=0.8, max_rules=20):
        union = tuple(sorted(set(r.antecedent) | set(r.consequent)))
        assert oracle[len(union)][union] == round(r.support * res.n_txns)


def test_ruleset_arrays_match_bruteforce(mined):
    """Vectorized RuleSet counts + float32 device metrics vs the oracle."""
    res, oracle = mined
    sup = {}
    for d in oracle.values():
        sup.update(d)
    rs = generate_ruleset(res, min_confidence=0.7)
    assert len(rs) > 0
    n = res.n_txns
    from repro.core.bitset import unpack_itemsets
    antes = unpack_itemsets(rs.ante_masks)
    conss = unpack_itemsets(rs.cons_masks)
    for i in range(len(rs)):
        union = tuple(sorted(set(antes[i]) | set(conss[i])))
        assert set(antes[i]) & set(conss[i]) == set()
        assert rs.union_counts[i] == sup[union]
        assert rs.ante_counts[i] == sup[antes[i]]
        assert rs.cons_counts[i] == sup[conss[i]]
        conf = sup[union] / sup[antes[i]]
        lift = conf * n / sup[conss[i]]
        lev = sup[union] / n - (sup[antes[i]] / n) * (sup[conss[i]] / n)
        np.testing.assert_allclose(rs.confidence[i], conf, rtol=1e-6)
        np.testing.assert_allclose(rs.lift[i], lift, rtol=1e-6)
        np.testing.assert_allclose(rs.leverage[i], lev, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(rs.score[i],
                                   np.float32(rs.confidence[i]) *
                                   np.float32(rs.lift[i]), rtol=1e-6)
    # rank order is (confidence, lift) descending on the exact metrics
    _, conf64, lift64, _ = rs.exact_metrics()
    keys = list(zip(-conf64, -lift64))
    assert keys == sorted(keys)


def result_from_oracle(txns, n_items, min_sup):
    """MiningResult built straight from the sequential oracle's levels —
    lets rule-layer property tests skip the miner entirely."""
    levels_dict = sequential_apriori(txns, min_sup)
    levels = {}
    for k, d in levels_dict.items():
        if not d:
            continue
        keys = sorted(d)
        levels[k] = (pack_itemsets(keys, n_items),
                     np.array([d[t] for t in keys], np.int64))
    return MiningResult(algorithm="oracle", min_sup=min_sup, n_txns=len(txns),
                        n_items=n_items, levels=levels, phases=[],
                        total_seconds=0.0, dispatches=0, compiles=0)


@given(st.lists(st.lists(st.integers(0, 7), min_size=1, max_size=6)
                .map(lambda x: sorted(set(x))), min_size=4, max_size=25),
       st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_rule_metrics_invariant_under_relabeling(txn_sets, perm_seed):
    """Property: relabeling the item catalog permutes rules but leaves every
    metric (support/confidence/lift/leverage) unchanged."""
    n_items = 8
    perm = np.random.default_rng(perm_seed).permutation(n_items)
    relabeled = [sorted(int(perm[i]) for i in t) for t in txn_sets]

    def key_set(txns):
        res = result_from_oracle(txns, n_items, min_sup=0.3)
        return {(r.antecedent, r.consequent,
                 round(r.support, 9), round(r.confidence, 9),
                 round(r.lift, 9), round(r.leverage, 9))
                for r in generate_rules(res, min_confidence=0.5)}

    def relabel(rule_key):
        a, c, *metrics = rule_key
        return (tuple(sorted(int(perm[i]) for i in a)),
                tuple(sorted(int(perm[i]) for i in c)), *metrics)

    assert {relabel(k) for k in key_set(txn_sets)} == key_set(relabeled)
