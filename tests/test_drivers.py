"""All seven algorithm drivers vs the sequential oracle (the paper's integrity
claim), plus checkpoint/resume and straggler handling."""

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import ALGORITHMS, mine, sequential_apriori
from repro.core.mapreduce import MapReduceRuntime

ALGOS = sorted(ALGORITHMS)


def _mk_txns(seed, n_items=24, n_txns=200, density=0.3):
    rng = np.random.default_rng(seed)
    base = rng.random((4, n_items)) < density * 1.5
    txns = []
    for _ in range(n_txns):
        pat = base[rng.integers(4)]
        row = np.where(rng.random(n_items) < 0.85, pat,
                       rng.random(n_items) < density / 2)
        t = np.nonzero(row)[0].tolist()
        txns.append(t if t else [int(rng.integers(n_items))])
    return txns


@pytest.fixture(scope="module")
def dataset():
    txns = _mk_txns(0)
    oracle = sequential_apriori(txns, 0.25)
    return txns, oracle


@pytest.mark.parametrize("algo", ALGOS)
def test_algorithm_matches_oracle(dataset, algo):
    txns, oracle = dataset
    res = mine(txns, n_items=24, min_sup=0.25, algorithm=algo)
    mined = res.itemsets()
    assert set(mined) == set(oracle)
    for k in oracle:
        assert mined[k] == oracle[k], f"level {k} differs for {algo}"


@given(st.integers(1, 10_000), st.sampled_from(["vfpc", "optimized_vfpc",
                                                "etdpc", "optimized_etdpc"]))
@settings(max_examples=8, deadline=None)
def test_property_random_datasets(seed, algo):
    """Property: paper algorithms == oracle on random correlated datasets."""
    txns = _mk_txns(seed, n_items=18, n_txns=120)
    min_sup = 0.3
    oracle = sequential_apriori(txns, min_sup)
    res = mine(txns, n_items=18, min_sup=min_sup, algorithm=algo)
    assert res.itemsets() == oracle


def test_fewer_dispatches_than_spc(dataset):
    """The whole point of the paper: combined passes → fewer jobs."""
    txns, _ = dataset
    n = {}
    for algo in ["spc", "fpc", "vfpc", "optimized_vfpc"]:
        res = mine(txns, n_items=24, min_sup=0.25, algorithm=algo)
        n[algo] = res.dispatches
    assert n["fpc"] < n["spc"]
    assert n["vfpc"] <= n["spc"]
    assert n["optimized_vfpc"] == n["vfpc"]


def test_optimized_generates_superset_candidates(dataset):
    txns, _ = dataset
    plain = mine(txns, n_items=24, min_sup=0.25, algorithm="vfpc")
    opt = mine(txns, n_items=24, min_sup=0.25, algorithm="optimized_vfpc")
    # same frequent itemsets, but ≥ candidates in multi-pass phases
    tot_plain = sum(sum(p.candidate_counts) for p in plain.phases)
    tot_opt = sum(sum(p.candidate_counts) for p in opt.phases)
    assert tot_opt >= tot_plain
    assert opt.itemsets() == plain.itemsets()


def test_checkpoint_resume(tmp_path, dataset):
    txns, oracle = dataset
    d = str(tmp_path / "ck")
    full = mine(txns, n_items=24, min_sup=0.25, algorithm="optimized_vfpc",
                checkpoint_dir=d)
    # resume from the final checkpoint: must terminate immediately and agree
    res = mine(txns, n_items=24, min_sup=0.25, algorithm="optimized_vfpc",
               checkpoint_dir=d, resume=True)
    assert res.itemsets() == full.itemsets()
    assert res.n_phases <= 1  # nothing left to do after restore


def test_checkpoint_mid_run_restart(tmp_path):
    """Kill after Job1 (simulated via max_k), restart, same answer."""
    txns = _mk_txns(3)
    oracle = sequential_apriori(txns, 0.25)
    d = str(tmp_path / "ck2")
    partial = mine(txns, n_items=24, min_sup=0.25, algorithm="vfpc",
                   checkpoint_dir=d, max_k=2)  # stops early, checkpointed
    res = mine(txns, n_items=24, min_sup=0.25, algorithm="vfpc",
               checkpoint_dir=d, resume=True)
    assert res.itemsets() == oracle


def test_retry_recovers_injected_failure():
    """A counting job that raises (injected shard failure) is re-dispatched
    after rescatter and the result stays bit-identical; exhausting
    max_retries propagates the error (DESIGN.md §11)."""
    rng = np.random.default_rng(7)
    txns = [sorted(set(rng.integers(0, 24, rng.integers(2, 9)).tolist()))
            for _ in range(150)]
    oracle = sequential_apriori(txns, 0.2)
    calls = {"n": 0}

    def fail_once(event, k):
        if event == "count_dispatch":
            calls["n"] += 1
            if calls["n"] == 2:
                raise RuntimeError("injected shard failure")

    res = mine(txns, n_items=24, min_sup=0.2, algorithm="optimized_vfpc",
               count_hook=fail_once)
    assert res.retries == 1
    assert res.itemsets() == oracle

    def always_fail(event, k):
        if event == "count_dispatch":
            raise RuntimeError("dead shard")

    with pytest.raises(RuntimeError, match="dead shard"):
        mine(txns, n_items=24, min_sup=0.2, count_hook=always_fail,
             max_retries=1)


def test_runtime_stats_accumulate(dataset):
    txns, _ = dataset
    rt = MapReduceRuntime()
    mine(txns, n_items=24, min_sup=0.25, algorithm="spc", runtime=rt)
    assert rt.stats.dispatches >= 3
    assert rt.stats.compiles >= 1
    assert rt.stats.rows_counted > 0
