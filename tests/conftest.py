import os
import sys

# tests see ONE device; the dry-run (and only it) forces 512 (assignment rule)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
