"""RuleStore / multi-tenant arena (DESIGN.md §12): layout invariants,
mixed-tenant ↔ per-tenant bit-identical equivalence (example-based + a
hypothesis property across tenant counts, rule-set sizes and impl families),
and swap atomicity under a concurrent writer (extends the PR 5 single-tenant
atomicity test to multi-tenant mixed query streams)."""

import threading

import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from loadgen import make_ruleset
from repro.core.bitset import WORD_BITS, n_words
from repro.serving import DEFAULT_TENANT, RuleServeEngine, RuleStore

# (seed, n_items, min_confidence) pool — mined once per module, reused by the
# property test to vary tenant counts and rule-set sizes cheaply
POOL_SPECS = [(7, 12, 0.6), (11, 9, 0.55), (23, 16, 0.7), (5, 12, 0.8)]


@pytest.fixture(scope="module")
def pool():
    out = []
    for seed, n_items, conf in POOL_SPECS:
        rules, baskets = make_ruleset(seed, n_items=n_items,
                                      min_confidence=conf)
        assert len(rules) > 0
        out.append((rules, baskets))
    return out


def recs_key(recs):
    """Bit-identity projection of one query's recommendations."""
    return [(r.consequent, r.confidence, r.lift, np.float32(r.score))
            for r in recs]


# -- arena layout --------------------------------------------------------------


def test_single_tenant_layout_matches_pr5(pool):
    """One tenant ⇒ no tag bits: the arena is byte-identical to the
    RuleSet's own packed masks (zero-overhead generalization)."""
    rules, _ = pool[0]
    state = RuleStore(rules).state
    assert state.tagged is False
    assert state.n_items == rules.n_items
    assert state.W == rules.ante_masks.shape[1]
    np.testing.assert_array_equal(state.ante_masks, rules.ante_masks)
    np.testing.assert_array_equal(state.cons_masks, rules.cons_masks)
    assert state.slots[DEFAULT_TENANT] is None
    assert tuple(state.tenants) == (DEFAULT_TENANT,)


def test_multi_tenant_layout(pool):
    (ra, _), (rb, _) = pool[0], pool[1]
    store = RuleStore(tenants={"A": ra, "B": rb})
    state = store.state
    assert state.tagged and len(state) == len(ra) + len(rb)
    base = max(ra.n_items, rb.n_items)
    assert state.n_items_base == base
    assert state.n_items == base + 2
    assert state.W == n_words(base + 2)
    assert state.offsets == {"A": 0, "B": len(ra)}
    np.testing.assert_array_equal(
        state.tenant_ids, [0] * len(ra) + [1] * len(rb))
    # every antecedent row carries exactly its tenant's tag bit
    for tenant, rules in (("A", ra), ("B", rb)):
        slot = state.slots[tenant]
        off = state.offsets[tenant]
        word, bit = slot // WORD_BITS, np.uint32(1 << (slot % WORD_BITS))
        rows = state.ante_masks[off:off + len(rules)]
        assert ((rows[:, word] & bit) != 0).all()
        other = state.slots["B" if tenant == "A" else "A"]
        ow, ob = other // WORD_BITS, np.uint32(1 << (other % WORD_BITS))
        assert ((rows[:, ow] & ob) == 0).all()
        # consequent masks carry no tag bits (host decode untouched)
        cons = state.cons_masks[off:off + len(rules)]
        w_t = rules.cons_masks.shape[1]
        np.testing.assert_array_equal(cons[:, :w_t], rules.cons_masks)
        assert (cons[:, w_t:] == 0).all()


def test_pack_tags_and_clips(pool):
    (ra, _), (rb, _) = pool[0], pool[1]   # rb has fewer items (9 < 12)
    store = RuleStore(tenants={"A": ra, "B": rb})
    state = store.state
    # item 10 is valid for A (12 items) but out of B's 9-item catalog
    packed = state.pack([("A", [1, 10]), ("B", [1, 10])])
    sa, sb = state.slots["A"], state.slots["B"]
    row_a, row_b = packed[0], packed[1]
    assert row_a[10 // WORD_BITS] & np.uint32(1 << (10 % WORD_BITS))
    assert not (row_b[10 // WORD_BITS] & np.uint32(1 << (10 % WORD_BITS)))
    assert row_a[sa // WORD_BITS] & np.uint32(1 << (sa % WORD_BITS))
    assert row_b[sb // WORD_BITS] & np.uint32(1 << (sb % WORD_BITS))
    assert not (row_a[sb // WORD_BITS] & np.uint32(1 << (sb % WORD_BITS)))
    with pytest.raises(KeyError):
        state.pack([("nobody", [1])])


def test_store_requires_exactly_one_init_form(pool):
    rules, _ = pool[0]
    with pytest.raises(ValueError):
        RuleStore()
    with pytest.raises(ValueError):
        RuleStore(rules, tenants={"A": rules})
    with pytest.raises(ValueError):
        RuleStore(tenants={"A": rules, "B": rules}).state.rules


# -- mixed-tenant ↔ per-tenant equivalence -------------------------------------


def _mixed_vs_single(pool_slice, impl, n_queries=12, top_k=3,
                     dedup=True):
    tenants = {f"t{i}": rules for i, (rules, _) in enumerate(pool_slice)}
    engines = {f"t{i}": RuleServeEngine(rules, impl=impl, top_k=top_k,
                                        dedup_consequents=dedup,
                                        autotune=False)
               for i, (rules, _) in enumerate(pool_slice)}
    eng = RuleServeEngine(RuleStore(tenants=tenants), impl=impl,
                          top_k=top_k, dedup_consequents=dedup,
                          autotune=False)
    # interleave tenants inside one batch so the fused dispatch is mixed
    mixed, want = [], []
    for q in range(n_queries):
        name = f"t{q % len(pool_slice)}"
        basket = pool_slice[q % len(pool_slice)][1][q % 40]
        mixed.append((name, basket))
        want.append(recs_key(engines[name].query([basket])[0]))
    got = [recs_key(r) for r in eng.query(mixed)]
    assert got == want


@pytest.mark.parametrize("impl", ["jnp", "matmul", "pallas_interpret"])
def test_mixed_equals_per_tenant(pool, impl):
    _mixed_vs_single(pool[:3], impl)


def test_mixed_equals_per_tenant_no_dedup(pool):
    _mixed_vs_single(pool[:2], "jnp", dedup=False, top_k=5)


@settings(max_examples=12, deadline=None)
@given(data=st.data())
def test_property_mixed_equals_per_tenant(pool, data):
    """Across random tenant subsets (with repeats ⇒ different sizes), query
    mixes, top-k and impl families: serving through the packed arena is
    bit-identical to one engine per tenant."""
    n_tenants = data.draw(st.integers(1, 4), label="n_tenants")
    picks = data.draw(st.lists(st.integers(0, len(pool) - 1),
                               min_size=n_tenants, max_size=n_tenants),
                      label="rulesets")
    impl = data.draw(st.sampled_from(["jnp", "matmul", "pallas_interpret"]),
                     label="impl")
    top_k = data.draw(st.integers(1, 6), label="top_k")
    qidx = data.draw(st.lists(st.integers(0, 39), min_size=1, max_size=10),
                     label="queries")

    slice_ = [pool[i] for i in picks]
    tenants = {f"t{i}": rules for i, (rules, _) in enumerate(slice_)}
    eng = RuleServeEngine(RuleStore(tenants=tenants), impl=impl,
                          top_k=top_k, autotune=False)
    singles = {f"t{i}": RuleServeEngine(rules, impl=impl, top_k=top_k,
                                        autotune=False)
               for i, (rules, _) in enumerate(slice_)}
    mixed, want = [], []
    for j, q in enumerate(qidx):
        name = f"t{j % len(slice_)}"
        basket = slice_[j % len(slice_)][1][q]
        mixed.append((name, basket))
        want.append(recs_key(singles[name].query([basket])[0]))
    got = [recs_key(r) for r in eng.query(mixed)]
    assert got == want


# -- swap atomicity under concurrency ------------------------------------------


def test_multi_tenant_swap_is_atomic(pool):
    """Writer hammers swap_rules("A") between two RuleSets while a reader
    serves mixed-tenant batches: every answer for A matches *exactly* one of
    the two sets' single-engine answers (never a torn mixture), and B's
    answers are never disturbed."""
    (ra1, baskets_a), (rb, baskets_b), (ra2, _) = pool[0], pool[1], pool[2]
    store = RuleStore(tenants={"A": ra1, "B": rb})
    eng = RuleServeEngine(store, impl="jnp", top_k=3, autotune=False)

    qa = [baskets_a[i] for i in range(6)]
    qb = [baskets_b[i] for i in range(6)]
    want_a = {}
    for tag, rules in (("v1", ra1), ("v2", ra2)):
        single = RuleServeEngine(rules, impl="jnp", top_k=3, autotune=False)
        want_a[tag] = [recs_key(r) for r in single.query(qa)]
    want_b = [recs_key(r)
              for r in RuleServeEngine(rb, impl="jnp", top_k=3,
                                       autotune=False).query(qb)]
    mixed = [p for ab in zip([("A", b) for b in qa],
                             [("B", b) for b in qb]) for p in ab]

    n_swaps = 6
    errors = []

    def writer():
        try:
            for i in range(n_swaps):
                store.swap_rules("A", ra2 if i % 2 == 0 else ra1)
        except Exception as e:             # pragma: no cover
            errors.append(e)

    wt = threading.Thread(target=writer)
    wt.start()
    for _ in range(12):
        got = [recs_key(r) for r in eng.query(mixed)]
        got_a, got_b = got[0::2], got[1::2]
        # the whole batch came from ONE consistent arena snapshot
        assert got_a in (want_a["v1"], want_a["v2"])
        assert got_b == want_b
    wt.join()
    assert not errors
    assert store.version("A") == n_swaps
    assert store.version("B") == 0
    final = [recs_key(r) for r in eng.query(mixed)][0::2]
    assert final == want_a["v1" if n_swaps % 2 == 0 else "v2"]


def test_swap_registers_new_tenant(pool):
    (ra, baskets_a), (rb, baskets_b) = pool[0], pool[1]
    store = RuleStore(tenants={"A": ra})
    eng = RuleServeEngine(store, impl="jnp", top_k=3, autotune=False)
    before = [recs_key(r) for r in eng.query([("A", baskets_a[0])])]
    store.swap_rules("B", rb)            # registration bumps to tagged arena
    assert store.version("B") == 0 and store.state.tagged
    after = [recs_key(r) for r in eng.query([("A", baskets_a[0]),
                                             ("B", baskets_b[0])])]
    assert after[0] == before[0]         # A's answers survive the re-layout
    single_b = RuleServeEngine(rb, impl="jnp", top_k=3, autotune=False)
    assert after[1] == recs_key(single_b.query([baskets_b[0]])[0])
