"""RuleServeEngine: brute-force top-k agreement, jnp vs Pallas-interpret
bit-exactness, and policy-fused vs per-batch dispatch equivalence."""

import numpy as np
import pytest

from repro.core import generate_ruleset, mine
from repro.core.bitset import pack_itemsets
from repro.kernels.rule_match import rule_scores_jnp, rule_scores_pallas
from repro.serving import RuleServeEngine


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(7)
    base = rng.random((3, 12)) < 0.5
    txns = []
    for _ in range(120):
        pat = base[rng.integers(3)]
        row = np.where(rng.random(12) < 0.85, pat, rng.random(12) < 0.1)
        txns.append(np.nonzero(row)[0].tolist() or [0])
    res = mine(txns, n_items=12, min_sup=0.3)
    rules = generate_ruleset(res, min_confidence=0.6)
    assert len(rules) > 5
    baskets = [sorted(set(t[:-1])) or [0] for t in txns[:40]]
    return rules, baskets


def brute_matches(rules, basket, exclude_contained=True):
    """Rule indices firing for a basket, best score first (index-stable)."""
    from repro.core.bitset import unpack_itemsets
    antes = unpack_itemsets(rules.ante_masks)
    conss = unpack_itemsets(rules.cons_masks)
    b = set(basket)
    hits = [i for i in range(len(rules))
            if set(antes[i]) <= b
            and not (exclude_contained and set(conss[i]) <= b)]
    return sorted(hits, key=lambda i: (-rules.score[i], i)), conss


def test_engine_matches_bruteforce(setup):
    rules, baskets = setup
    eng = RuleServeEngine(rules, impl="jnp", dedup_consequents=False)
    recs = eng.query(baskets, top_k=len(rules))
    for basket, got in zip(baskets, recs):
        hits, conss = brute_matches(rules, basket)
        want = [(conss[i], np.float32(rules.score[i])) for i in hits]
        assert [(r.consequent, np.float32(r.score)) for r in got] == want


def test_engine_dedups_consequents(setup):
    rules, baskets = setup
    eng = RuleServeEngine(rules, impl="jnp", top_k=3)
    for got, basket in zip(eng.query(baskets), baskets):
        conss = [r.consequent for r in got]
        assert len(set(conss)) == len(conss)
        assert len(conss) <= 3
        scores = [r.score for r in got]
        assert scores == sorted(scores, reverse=True)
        for r in got:    # novelty: never recommend what's already there
            assert not set(r.consequent) <= set(basket)


def test_kernel_paths_bit_exact(setup):
    rules, baskets = setup
    packed = pack_itemsets(baskets, rules.n_items)
    for excl in (True, False):
        ref = np.asarray(rule_scores_jnp(
            rules.ante_masks, rules.cons_masks, rules.score, packed,
            q_block=16, exclude_contained=excl))
        pal = np.asarray(rule_scores_pallas(
            rules.ante_masks, rules.cons_masks, rules.score, packed,
            bq=16, br=32, exclude_contained=excl, interpret=True))
        np.testing.assert_array_equal(ref, pal)


def test_engine_impls_agree_exactly(setup):
    rules, baskets = setup
    a = RuleServeEngine(rules, impl="jnp").query(baskets)
    b = RuleServeEngine(rules, impl="pallas_interpret").query(baskets)
    assert a == b


def test_fused_vs_per_batch_equivalence(setup):
    rules, baskets = setup
    batches = [baskets[i:i + 5] for i in range(0, len(baskets), 5)]
    spc = RuleServeEngine(rules, impl="jnp", algorithm="spc")
    fused = RuleServeEngine(rules, impl="jnp", algorithm="optimized_vfpc")
    r_spc, rec_spc = spc.serve(batches)
    r_fused, rec_fused = fused.serve(batches)
    assert r_spc == r_fused
    assert all(r.n_batches == 1 for r in rec_spc)
    assert len(rec_spc) == len(batches)
    assert any(r.n_batches > 1 for r in rec_fused)       # policy actually fuses
    assert len(rec_fused) < len(batches)
    assert sum(r.n_queries for r in rec_fused) == len(baskets)


def test_unknown_items_and_empty_baskets(setup):
    rules, _ = setup
    recs = eng_recs = RuleServeEngine(rules, impl="jnp").query(
        [[], [999, 10_000], [0, 1, 2, 999]])
    assert recs[0] == [] and recs[1] == []      # nothing known → nothing fires
    # unknown ids are ignored, known prefix still answered like [0, 1, 2]
    clean = RuleServeEngine(rules, impl="jnp").query([[0, 1, 2]])
    assert eng_recs[2] == clean[0]


def test_top_k_zero_returns_nothing(setup):
    rules, baskets = setup
    recs = RuleServeEngine(rules, impl="jnp").query(baskets[:3], top_k=0)
    assert recs == [[], [], []]


def test_inf_score_rules_still_decode(setup):
    """+inf scores (legacy missing-consequent lift) are legal rank keys; only
    -inf is the kernel's no-match sentinel."""
    import dataclasses
    rules, baskets = setup
    boosted = dataclasses.replace(
        rules, score=np.where(np.arange(len(rules)) == 0, np.inf,
                              rules.score).astype(np.float32))
    recs = RuleServeEngine(boosted, impl="jnp", dedup_consequents=False).query(
        baskets, top_k=3)
    hits, _ = brute_matches(rules, baskets[0])
    if 0 in hits:      # the boosted rule fires → it must rank first, not hide
        assert recs[0][0].score == np.inf
    assert any(len(r) > 0 for r in recs)


def test_empty_ruleset_serves_empty():
    from repro.core.drivers import MiningResult
    res = MiningResult(algorithm="spc", min_sup=0.9, n_txns=4, n_items=8,
                       levels={}, phases=[], total_seconds=0.0,
                       dispatches=0, compiles=0)
    rules = generate_ruleset(res)
    assert len(rules) == 0
    results, records = RuleServeEngine(rules, impl="jnp").serve([[[0, 1]]])
    assert results == [[[]]] and records == []