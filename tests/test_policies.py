"""Policy decision tables — line-by-line against the paper's pseudo-code."""

from repro.core.policy import (DPCPolicy, ETDPCPolicy, FPCPolicy, PhaseStats,
                               SPCPolicy, VFPCPolicy)


def S(c, f, e):
    return PhaseStats(n_candidates=c, n_frequent_last=f, elapsed=e)


def test_spc_always_one():
    p = SPCPolicy()
    assert p.decide(None, None) == ("width", 1)
    assert p.decide(S(10, 5, 1.0), S(20, 9, 2.0)) == ("width", 1)


def test_fpc_fixed():
    p = FPCPolicy(npass=3)
    for _ in range(4):
        assert p.decide(S(10, 5, 1.0), None) == ("width", 3)


def test_vfpc_paper_algorithm3():
    """npass=2 while counts non-decreasing; +3 per decreasing phase; reset on rise."""
    p = VFPCPolicy()
    assert p.decide(None, None) == ("width", 2)
    assert p.decide(S(100, 1, 1), S(50, 1, 1)) == ("width", 2)     # rising
    assert p.decide(S(80, 1, 1), S(100, 1, 1)) == ("width", 5)     # falling: 2+3
    assert p.decide(S(40, 1, 1), S(80, 1, 1)) == ("width", 8)      # falling: 5+3
    assert p.decide(S(90, 1, 1), S(40, 1, 1)) == ("width", 2)      # rising: reset


def test_dpc_alpha_from_absolute_time():
    p = DPCPolicy(alpha_fast=2.0, beta=60.0, time_scale=1.0)
    assert p.decide(S(1, 1, 30.0), None) == ("budget_alpha", 2.0)  # fast phase
    assert p.decide(S(1, 1, 90.0), None) == ("budget_alpha", 1.0)  # slow phase


def test_etdpc_paper_algorithm4():
    p = ETDPCPolicy(beta1=40.0, beta2=60.0, time_scale=1.0)
    # ETprev < ET branch
    assert p.decide(S(1, 1, 30.0), S(1, 1, 10.0)) == ("budget_alpha", 3.0)  # ET<=β1
    assert p.decide(S(1, 1, 50.0), S(1, 1, 10.0)) == ("budget_alpha", 2.0)  # β1<ET<β2
    assert p.decide(S(1, 1, 80.0), S(1, 1, 10.0)) == ("budget_alpha", 1.0)  # ET>=β2
    # ETprev >= ET branch
    assert p.decide(S(1, 1, 10.0), S(1, 1, 20.0)) == ("budget_alpha", 3.0)  # ≥1.5×
    assert p.decide(S(1, 1, 10.0), S(1, 1, 12.0)) == ("budget_alpha", 2.0)  # <1.5×


def test_etdpc_time_scale():
    """β thresholds rescale but relative logic is unchanged (robustness claim)."""
    slow = ETDPCPolicy(time_scale=1.0)
    fast = ETDPCPolicy(time_scale=1e-3)
    assert slow.decide(S(1, 1, 30.0), S(1, 1, 10.0)) \
        == fast.decide(S(1, 1, 30.0e-3), S(1, 1, 10.0e-3))
