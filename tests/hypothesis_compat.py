"""Optional-hypothesis shim: property tests skip cleanly when hypothesis is
absent, while example-based tests in the same module keep running.

Usage::

    from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # degrade: decorators become skips
    HAVE_HYPOTHESIS = False

    class _Absorb:
        """Swallows any strategy-building expression (st.lists(...).map(...))."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _Absorb()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn
