"""Training substrate: convergence, fused phases, checkpoint/restart,
gradient compression, optimizer math."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.models import build_model
from repro.optim import AdamWConfig, adamw
from repro.train import (TrainLoop, all_steps, init_train_state,
                         load_checkpoint, make_train_step, save_checkpoint)


def _setup(algorithm="vfpc", **opt_kw):
    cfg = get_config("smollm-135m", smoke=True)
    model = build_model(cfg)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60, **opt_kw)
    return model, pipe, opt


def test_loss_decreases():
    model, pipe, opt = _setup()
    loop = TrainLoop(model, pipe, opt, algorithm="vfpc")
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    state, recs = loop.run(state, total_steps=16)
    assert recs[-1].mean_loss < recs[0].mean_loss
    assert sum(r.npass for r in recs) == 16


def test_fused_phase_equals_sequential_steps():
    """npass=3 fused dispatch == 3 single-step dispatches (bitwise-ish)."""
    model, pipe, opt = _setup()
    state1 = init_train_state(model, opt, jax.random.PRNGKey(0))
    state3 = jax.tree.map(lambda x: x.copy(), state1)
    b = [pipe.next_batch() for _ in range(3)]
    batch3 = {"tokens": np.stack([x[0] for x in b]),
              "labels": np.stack([x[1] for x in b])}
    fn1 = make_train_step(model, opt, npass=1, donate=False)
    fn3 = make_train_step(model, opt, npass=3, donate=False)
    for i in range(3):
        state1, _ = fn1(state1, {"tokens": batch3["tokens"][i:i+1],
                                 "labels": batch3["labels"][i:i+1]})
    state3, _ = fn3(state3, batch3)
    for a, c in zip(jax.tree.leaves(state1), jax.tree.leaves(state3)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), rtol=2e-2, atol=2e-2)


def test_checkpoint_roundtrip(tmp_path):
    model, pipe, opt = _setup()
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 7, state)
    assert all_steps(str(tmp_path)) == [7]
    tree, step = load_checkpoint(str(tmp_path), template=state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(tree)):
        assert np.asarray(a, np.float32).tolist() == np.asarray(b, np.float32).tolist()


def test_checkpoint_retention(tmp_path):
    model, pipe, opt = _setup()
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    for s in [1, 2, 3, 4, 5]:
        save_checkpoint(str(tmp_path), s, state, keep=2)
    assert all_steps(str(tmp_path)) == [4, 5]


def test_restart_resumes_step_count(tmp_path):
    model, pipe, opt = _setup()
    d = str(tmp_path / "ck")
    loop = TrainLoop(model, pipe, opt, algorithm="spc", checkpoint_dir=d)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    state, _ = loop.run(state, total_steps=6)
    # "crash" and restart from disk
    tmpl = jax.tree.map(lambda x: x, state)
    tree, step = load_checkpoint(d, template=tmpl)
    assert step == 6
    loop2 = TrainLoop(model, pipe, opt, algorithm="spc", checkpoint_dir=d)
    state2, recs2 = loop2.run(jax.device_put(tree), total_steps=10)
    assert int(state2["opt"]["step"]) == 10


def test_gradient_compression_converges():
    model, pipe, opt = _setup(compress=True)
    loop = TrainLoop(model, pipe, opt, algorithm="fpc")
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    state, recs = loop.run(state, total_steps=12)
    assert np.isfinite(recs[-1].mean_loss)
    assert recs[-1].mean_loss < recs[0].mean_loss


def test_compress_grads_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)}
    e = {"w": jnp.zeros((64,), jnp.float32)}
    total = jnp.zeros((64,), jnp.float32)
    raw = jnp.zeros((64,), jnp.float32)
    for _ in range(50):
        deq, e = adamw.compress_grads(g, e)
        total = total + deq["w"]
        raw = raw + g["w"]
    # error feedback keeps long-run average unbiased
    np.testing.assert_allclose(np.asarray(total), np.asarray(raw),
                               rtol=1e-2, atol=1e-2)


def test_adamw_schedule():
    opt = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(adamw.schedule(opt, jnp.asarray(5))) == 0.5
    assert abs(float(adamw.schedule(opt, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(adamw.schedule(opt, jnp.asarray(100))) - 0.1) < 1e-6


def test_data_pipeline_resume(tmp_path):
    """Restart continues the token stream rather than replaying it."""
    model, pipe, opt = _setup()
    d = str(tmp_path / "ck")
    loop = TrainLoop(model, pipe, opt, algorithm="spc", checkpoint_dir=d)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    state, _ = loop.run(state, total_steps=5)
    consumed = pipe._step
    assert consumed == 5
    # fresh process: new pipeline starts at 0; restore fast-forwards it
    from repro.data.tokens import TokenPipeline
    pipe2 = TokenPipeline(vocab_size=model.cfg.vocab_size, seq_len=32,
                          global_batch=4)
    loop2 = TrainLoop(model, pipe2, opt, algorithm="spc", checkpoint_dir=d)
    loop2.restore_data_cursor()
    assert pipe2._step == consumed
    t_next, _ = pipe2.next_batch()
    pipe_ref = TokenPipeline(vocab_size=model.cfg.vocab_size, seq_len=32,
                             global_batch=4)
    for _ in range(consumed):
        pipe_ref.next_batch()
    t_want, _ = pipe_ref.next_batch()
    assert (t_next == t_want).all()


def test_nan_phase_recovery(tmp_path):
    """A NaN'd phase restores from checkpoint instead of corrupting state."""
    model, pipe, opt = _setup()
    d = str(tmp_path / "ck")
    loop = TrainLoop(model, pipe, opt, algorithm="spc", checkpoint_dir=d,
                     ckpt_every_phases=1)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    state, _ = loop.run(state, total_steps=3)
    # poison params → next phase NaNs → loop restores from disk
    bad = jax.tree.map(lambda x: x, state)
    bad["params"]["embed"]["table"] = bad["params"]["embed"]["table"] * jnp.nan
    state2, recs = loop.run(bad, total_steps=4)
    assert any(r.renan for r in recs)
    assert np.isfinite(recs[-1].mean_loss)
