"""Candidate generation vs brute-force set semantics (property-based)."""

from itertools import combinations

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.bitset import pack_itemsets, unpack_itemsets
from repro.core.candidates import apriori_gen, join, non_apriori_gen, prune

N_ITEMS = 40


def brute_join(prev_sets, k_prev):
    """Classic F_{k-1}×F_{k-1} join on sorted tuples."""
    prev = sorted(prev_sets)
    out = set()
    for i in range(len(prev)):
        for j in range(i + 1, len(prev)):
            a, b = prev[i], prev[j]
            if a[:-1] == b[:-1] and a[-1] != b[-1]:
                out.add(tuple(sorted(set(a) | set(b))))
    return out


def brute_prune(cands, prev_sets, k_prev):
    prev = set(prev_sets)
    return {c for c in cands
            if all(sub in prev for sub in combinations(c, k_prev))}


def ksets(k):
    return st.lists(
        st.lists(st.integers(0, N_ITEMS - 1), min_size=k, max_size=k,
                 unique=True).map(lambda x: tuple(sorted(x))),
        min_size=0, max_size=25, unique=True)


@given(ksets(3))
@settings(max_examples=40, deadline=None)
def test_join_matches_bruteforce(prev):
    masks = pack_itemsets([list(t) for t in prev], N_ITEMS)
    got = set(unpack_itemsets(join(masks, 3)))
    assert got == brute_join(prev, 3)


@given(ksets(2))
@settings(max_examples=40, deadline=None)
def test_apriori_gen_matches_bruteforce(prev):
    masks = pack_itemsets([list(t) for t in prev], N_ITEMS)
    got = set(unpack_itemsets(apriori_gen(masks, 2)))
    want = brute_prune(brute_join(prev, 2), prev, 2)
    assert got == want


@given(ksets(3))
@settings(max_examples=40, deadline=None)
def test_non_apriori_gen_superset(prev):
    """join-only output ⊇ join+prune output (the skipped-pruning invariant)."""
    masks = pack_itemsets([list(t) for t in prev], N_ITEMS)
    unpruned = set(unpack_itemsets(non_apriori_gen(masks, 3)))
    pruned = set(unpack_itemsets(apriori_gen(masks, 3)))
    assert pruned <= unpruned


def test_join_blocked_consistency():
    """Blocked evaluation must be independent of block size."""
    rng = np.random.default_rng(0)
    sets = {tuple(sorted(rng.choice(N_ITEMS, 4, replace=False))) for _ in range(300)}
    masks = pack_itemsets([list(t) for t in sets], N_ITEMS)
    a = set(unpack_itemsets(join(masks, 4, block=7)))
    b = set(unpack_itemsets(join(masks, 4, block=1024)))
    assert a == b


def test_prune_keeps_frequent_closure():
    prev = [(0, 1), (0, 2), (1, 2), (3, 4)]
    masks = pack_itemsets([list(t) for t in prev], N_ITEMS)
    c = join(masks, 2)
    kept = set(unpack_itemsets(prune(c, masks, 2)))
    assert kept == {(0, 1, 2)}
