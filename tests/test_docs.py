"""Docs consistency: DESIGN.md §-references and README quickstart commands.

Module docstrings across the repo cite architecture sections as
``DESIGN.md §N``; this gate fails when a cited section does not exist, and
when a README command names a module or script that is not in the tree —
so the docs cannot silently rot as the code moves.
"""

import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


def _py_files():
    for sub in ("src", "tests", "benchmarks", "examples"):
        yield from (ROOT / sub).rglob("*.py")


def _design_sections():
    text = (ROOT / "DESIGN.md").read_text()
    return {int(m) for m in re.findall(r"(?m)^## §(\d+)", text)}


def test_design_section_references_exist():
    sections = _design_sections()
    assert sections, "DESIGN.md has no '## §N' sections"
    missing = []
    for path in _py_files():
        for n in re.findall(r"DESIGN\.md §(\d+)", path.read_text()):
            if int(n) not in sections:
                missing.append((str(path.relative_to(ROOT)), int(n)))
    assert not missing, (
        f"dangling DESIGN.md § references (existing: {sorted(sections)}): "
        f"{missing}")


def test_design_references_from_markdown():
    """README/CHANGES §-citations must resolve too."""
    sections = _design_sections()
    for name in ("README.md", "CHANGES.md"):
        text = (ROOT / name).read_text()
        for n in re.findall(r"DESIGN\.md[^#\n]{0,20}§(\d+)", text):
            assert int(n) in sections, f"{name} cites missing DESIGN.md §{n}"


def test_readme_exists_and_commands_resolve():
    readme = ROOT / "README.md"
    assert readme.exists(), "top-level README.md is required"
    text = readme.read_text()

    # `python -m pkg.mod` → src/pkg/mod.py or <repo>/pkg/mod.py (namespace pkg)
    mods = {m for m in re.findall(r"python -m ([A-Za-z0-9_.]+)", text)
            if m.split(".")[0] in ("repro", "benchmarks")}  # ours, not pytest
    assert mods, "README quickstart should show `python -m ...` commands"
    for mod in mods:
        rel = Path(*mod.split("."))
        candidates = [ROOT / "src" / rel.with_suffix(".py"),
                      ROOT / "src" / rel / "__init__.py",
                      ROOT / rel.with_suffix(".py"),
                      ROOT / rel / "__init__.py"]
        assert any(c.exists() for c in candidates), \
            f"README references `python -m {mod}` but no such module exists"

    # `python path/to/script.py` → the script must exist
    for script in re.findall(r"python ((?:examples|benchmarks)/[\w/]+\.py)", text):
        assert (ROOT / script).exists(), \
            f"README references `python {script}` but the file is missing"


def test_readme_mentions_tracked_benchmarks():
    text = (ROOT / "README.md").read_text()
    for record in ("BENCH_exec_time.json", "BENCH_kernels.json",
                   "BENCH_rules.json", "BENCH_stream.json",
                   "BENCH_costmodel.json", "BENCH_scaling.json"):
        assert record in text, f"README should cite {record} headline numbers"
        assert (ROOT / record).exists(), f"{record} missing from repo root"


@pytest.mark.parametrize("surface", [
    "repro.launch.mine", "repro.launch.serve_rules", "repro.launch.stream",
    "repro.launch.report",
    "examples/quickstart.py", "examples/recommend.py",
    "examples/stream_mine.py", "examples/mine_distributed.py",
    "benchmarks.bench_scaling",
])
def test_quickstart_surfaces_in_readme(surface):
    """The documented entry points stay documented."""
    assert surface in (ROOT / "README.md").read_text()


def test_matmul_kernel_family_documented():
    """The §10 counting-as-matmul subsystem stays documented: the README
    impl table, the DESIGN section, and the roofline/plan surfaces."""
    readme = (ROOT / "README.md").read_text()
    assert "Kernel implementation families" in readme
    for impl in ("matmul", "vertical_matmul", "matmul_pallas"):
        assert f"`{impl}`" in readme, f"README impl table must list {impl}"
    assert 10 in _design_sections()
    design = (ROOT / "DESIGN.md").read_text()
    for surface in ("junpack_bits", "tuned_plan", "count_kernel_roofline",
                    "count_winner", "XFER_OPS_PER_BYTE"):
        assert surface in design, f"DESIGN.md §10 must document {surface}"


def test_cluster_mesh_documented():
    """The §11 cluster-scale subsystem stays documented: the README
    distributed quickstart, the DESIGN section, and its public surfaces."""
    readme = (ROOT / "README.md").read_text()
    assert "Distributed quickstart" in readme
    for flag in ("--n-cand-shards", "--coordinator", "--balance-shards"):
        assert flag in readme, f"README distributed quickstart must show {flag}"
    assert 11 in _design_sections()
    design = (ROOT / "DESIGN.md").read_text()
    for surface in ("init_distributed", "make_mining_mesh", "choose_mesh",
                    "should_rebalance", "balance_masks", "rescatter"):
        assert surface in design, f"DESIGN.md §11 must document {surface}"


def test_multi_tenant_serving_documented():
    """The §12 multi-tenant serving layer stays documented: the README
    quickstart flags + headline, the DESIGN section, and its public
    surfaces."""
    readme = (ROOT / "README.md").read_text()
    for flag in ("--tenants", "--rate-qps", "--latency-slo-ms"):
        assert flag in readme, f"README §12 quickstart must show {flag}"
    for surface in ("RuleStore", "OpenLoopServer", "swap_rules",
                    "qps", "tests/loadgen.py"):
        assert surface in readme, f"README must document {surface}"
    assert 12 in _design_sections()
    design = (ROOT / "DESIGN.md").read_text()
    for surface in ("RuleStore", "ArenaState", "should_admit",
                    "OpenLoopServer", "swap_rules", "tag bit",
                    "qps-at-p99-SLO", "dispatch_cost_fn"):
        assert surface in design, f"DESIGN.md §12 must document {surface}"
    bench = (ROOT / "BENCH_rules.json").read_text()
    assert "open_loop" in bench and "qps_at_slo" in bench, \
        "BENCH_rules.json must carry the §12 open-loop arm"


def test_measured_policy_documented():
    """The cost-model subsystem's public surfaces stay documented: the
    `measured` algorithm row in the README table and the §9 architecture
    section it cites."""
    readme = (ROOT / "README.md").read_text()
    assert "`measured`" in readme and "BENCH_costmodel.json" in readme
    assert 9 in _design_sections()
    design = (ROOT / "DESIGN.md").read_text()
    for primitive in ("choose_width", "should_remine", "choose_fusion",
                      "should_speculate"):
        assert primitive in design, f"DESIGN.md §9 must document {primitive}"


def test_observability_documented():
    """The §13 observability layer stays documented: the README quickstart
    (trace/metrics flags, Perfetto, report + validate commands), the
    DESIGN section, and its public surfaces."""
    readme = (ROOT / "README.md").read_text()
    assert "## Observability" in readme
    for flag in ("--trace-out", "--metrics-out", "ui.perfetto.dev",
                 "repro.obs.validate", "--trace trace.json"):
        assert flag in readme, f"README Observability quickstart must show {flag}"
    assert 13 in _design_sections()
    design = (ROOT / "DESIGN.md").read_text()
    for surface in ("Tracer", "FakeClock", "MonotonicClock", "NULL_TRACER",
                    "schema_version", "validate_snapshot", "add_span",
                    "serve.query", "mine.phase", "roofline_peak_frac",
                    "decision."):
        assert surface in design, f"DESIGN.md §13 must document {surface}"
