"""Device-resident phase pipeline: cross-impl equivalence, fused-vs-unfused
counting, async futures, speculative join, and the block autotuner."""

import json
import os

import numpy as np
import pytest

from repro.core import mine
from repro.core.bitset import pack_itemsets
from repro.core.candidates import join_pairs, speculative_join
from repro.core.mapreduce import MapReduceRuntime
from repro.core.phases import bucket_pad
from repro.core.policy import ALGORITHMS

ALGOS = sorted(ALGORITHMS)
IMPLS = ["jnp", "pallas_interpret", "vertical", "vertical_pallas_interpret"]


def _dataset(seed=0, n=90, n_items=20):
    rng = np.random.default_rng(seed)
    base = rng.random((4, n_items)) < 0.4
    txns = []
    for _ in range(n):
        pat = base[rng.integers(4)]
        row = np.where(rng.random(n_items) < 0.85, pat, rng.random(n_items) < 0.1)
        txns.append(np.nonzero(row)[0].tolist() or [0])
    return txns, n_items


def _levels_snapshot(res):
    return {k: (v[0].copy(), v[1].copy()) for k, v in sorted(res.levels.items())}


def _assert_levels_equal(a, b, ctx):
    assert a.keys() == b.keys(), ctx
    for k in a:
        np.testing.assert_array_equal(a[k][0], b[k][0],
                                      err_msg=f"{ctx}: masks at k={k}")
        np.testing.assert_array_equal(a[k][1], b[k][1],
                                      err_msg=f"{ctx}: counts at k={k}")


@pytest.mark.parametrize("algo", ALGOS)
def test_cross_impl_equivalence(algo):
    """mine() produces identical levels for every counting impl."""
    txns, n_items = _dataset()
    ref = None
    for impl in IMPLS:
        rt = MapReduceRuntime(impl=impl, autotune=False)
        res = mine(txns, n_items=n_items, min_sup=0.3, algorithm=algo,
                   runtime=rt)
        snap = _levels_snapshot(res)
        if ref is None:
            ref = snap
        else:
            _assert_levels_equal(ref, snap, f"{algo}/{impl}")


@pytest.mark.parametrize("algo", ["spc", "vfpc", "optimized_vfpc",
                                  "optimized_etdpc"])
def test_fused_matches_unfused(algo):
    """The fused (device-filter) path and the legacy unfused path agree."""
    txns, n_items = _dataset(seed=3)
    rt_f = MapReduceRuntime(autotune=False)
    res_f = mine(txns, n_items=n_items, min_sup=0.3, algorithm=algo,
                 runtime=rt_f, pipeline=True)
    rt_u = MapReduceRuntime(autotune=False)
    res_u = mine(txns, n_items=n_items, min_sup=0.3, algorithm=algo,
                 runtime=rt_u, pipeline=False)
    _assert_levels_equal(_levels_snapshot(res_f), _levels_snapshot(res_u), algo)
    assert rt_f.stats.fused_dispatches == rt_f.stats.dispatches
    assert rt_u.stats.fused_dispatches == 0
    # fused jobs move strictly fewer result bytes to the host
    assert rt_f.stats.bytes_to_host < rt_u.stats.bytes_to_host


def test_phase_count_filtered_matches_phase_count():
    """Runtime-level: fused keep mask == host-side threshold on plain counts."""
    txns, n_items = _dataset(seed=7)
    db = pack_itemsets(txns, n_items)
    rt = MapReduceRuntime(autotune=False)
    sharded = rt.scatter_db(db, n_items=n_items)
    rng = np.random.default_rng(0)
    cands = bucket_pad(db[rng.integers(0, len(db), 100)])
    min_count = 0.25 * len(txns)
    counts = rt.phase_count(sharded, cands)
    keep, fcounts = rt.phase_count_filtered(sharded, cands, min_count)
    np.testing.assert_array_equal(keep, counts >= min_count)
    np.testing.assert_array_equal(fcounts[keep], counts[keep])
    assert (fcounts[~keep] == 0).all()
    # mask-only transfer drops the counts payload entirely
    keep2, nothing = rt.phase_count_filtered(sharded, cands, min_count,
                                             with_counts=False)
    np.testing.assert_array_equal(keep2, keep)
    assert nothing is None


def test_count_future_is_async_handle():
    txns, n_items = _dataset(seed=11)
    db = pack_itemsets(txns, n_items)
    rt = MapReduceRuntime(autotune=False)
    sharded = rt.scatter_db(db, n_items=n_items)
    cands = bucket_pad(db[:64])
    fut = rt.phase_count_async(sharded, cands)
    first = fut.result()
    assert first.dtype == np.int64 and first.shape[0] == cands.shape[0]
    assert fut.ready()
    assert fut.result() is first          # result is cached, not re-fetched


def test_speculative_join_resolves_exactly():
    """Pair-filtering the speculative join reproduces join(L) byte-for-byte."""
    rng = np.random.default_rng(2)
    sets_ = {tuple(sorted(rng.choice(30, 3, replace=False))) for _ in range(300)}
    cands = pack_itemsets([list(s) for s in sets_], 30)
    keep = rng.random(cands.shape[0]) < 0.6
    spec = speculative_join(cands, 3)
    want = join_pairs(cands[keep], 3)[0]
    np.testing.assert_array_equal(spec.resolve(keep), want)


def test_join_methods_identical():
    rng = np.random.default_rng(4)
    sets_ = {tuple(sorted(rng.choice(40, 4, replace=False))) for _ in range(500)}
    masks = pack_itemsets([list(s) for s in sets_], 40)
    a, al, ar = join_pairs(masks, 4, method="prefix")
    b, bl, br = join_pairs(masks, 4, method="pairwise")
    np.testing.assert_array_equal(a, b)
    pa = {frozenset((int(x), int(y))) for x, y in zip(al, ar)}
    pb = {frozenset((int(x), int(y))) for x, y in zip(bl, br)}
    assert pa == pb


def test_autotuner_caches_in_process_and_on_disk(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "autotune.json"))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    import repro.kernels.autotune as at
    monkeypatch.setattr(at, "_memory_cache", {})
    cfg = at.tuned_blocks("vertical", C=300, T=200, W=1, kmax=3)
    assert cfg in at.CONFIGS["vertical"]
    disk = json.load(open(tmp_path / "autotune.json"))
    assert len(disk) == 1 and list(disk.values())[0] == cfg
    # second call: in-process hit (and disk content untouched)
    assert at.tuned_blocks("vertical", C=300, T=200, W=1, kmax=3) == cfg
    # interpret impls and REPRO_AUTOTUNE=0 return static defaults untimed
    assert at.tuned_blocks("vertical_pallas_interpret", C=300, T=200) == \
        at.DEFAULTS["vertical_pallas_interpret"]
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    assert at.tuned_blocks("vertical", C=9999, T=9999) == at.DEFAULTS["vertical"]


def test_overlap_stat_accumulates_when_speculating():
    """A run that speculates records the phase's spec time; overlap_seconds
    only grows when a job was genuinely in flight (never negative)."""
    txns, n_items = _dataset(seed=5, n=150)
    rt = MapReduceRuntime(autotune=False)
    res = mine(txns, n_items=n_items, min_sup=0.25,
               algorithm="optimized_vfpc", runtime=rt, pipeline=True)
    assert rt.stats.overlap_seconds >= 0.0
    assert res.overlap_seconds == rt.stats.overlap_seconds
    assert any(p.spec_seconds > 0 for p in res.phases) or \
        rt.stats.overlap_seconds == 0.0
