"""Serving engine: policy equivalence, EOS pruning analogy, dispatch counts."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import ServeEngine

ALGOS = ["spc", "fpc", "dpc", "vfpc", "etdpc", "optimized_vfpc", "optimized_etdpc"]


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, (4, 8)).astype(np.int32)
    return model, params, prompts


@pytest.mark.parametrize("algo", ALGOS)
def test_all_policies_same_output(served, algo):
    model, params, prompts = served
    base_eng = ServeEngine(model, params, cache_len=64, algorithm="spc")
    base, _ = base_eng.generate(prompts, max_new_tokens=20, eos_id=-1)
    eng = ServeEngine(model, params, cache_len=64, algorithm=algo)
    out, recs = eng.generate(prompts, max_new_tokens=20, eos_id=-1)
    np.testing.assert_array_equal(out, base)


def test_fused_policies_fewer_dispatches(served):
    model, params, prompts = served
    counts = {}
    for algo in ["spc", "fpc", "optimized_vfpc"]:
        eng = ServeEngine(model, params, cache_len=64, algorithm=algo)
        _, recs = eng.generate(prompts, max_new_tokens=20, eos_id=-1)
        counts[algo] = len(recs)
    assert counts["fpc"] < counts["spc"]
    assert counts["optimized_vfpc"] < counts["spc"]


def test_eos_trimming_and_waste(served):
    """Optimized engines emit tokens past EOS ('un-pruned candidates') but the
    phase-end filter trims them — outputs identical to the pruned engine."""
    model, params, prompts = served
    # find the eos that the greedy decode actually produces early
    probe = ServeEngine(model, params, cache_len=64, algorithm="spc")
    ref, _ = probe.generate(prompts, max_new_tokens=16, eos_id=-1)
    eos_id = int(ref[0, 3])  # forces row 0 to finish at step 3

    pruned = ServeEngine(model, params, cache_len=64, algorithm="fpc")
    out_p, recs_p = pruned.generate(prompts, max_new_tokens=16, eos_id=eos_id)
    opt = ServeEngine(model, params, cache_len=64, algorithm="optimized_vfpc")
    out_o, recs_o = opt.generate(prompts, max_new_tokens=16, eos_id=eos_id)

    np.testing.assert_array_equal(out_p, out_o)
    # after a row finishes, everything it emits is trimmed to pad
    row0 = out_o[0]
    stop = np.argmax(row0 == eos_id)
    assert (row0[stop + 1:] == 0).all()


def test_pipelined_dispatch_equivalence(served):
    """Depth-2 pipelined dispatch (EOS check lags one phase) is output-exact;
    it may only waste MORE post-EOS tokens, never change results."""
    model, params, prompts = served
    probe = ServeEngine(model, params, cache_len=64, algorithm="spc")
    ref, _ = probe.generate(prompts, max_new_tokens=16, eos_id=-1)
    eos_id = int(ref[0, 3])
    plain = ServeEngine(model, params, cache_len=64,
                        algorithm="optimized_vfpc")
    out_p, recs_p = plain.generate(prompts, max_new_tokens=16, eos_id=eos_id)
    piped = ServeEngine(model, params, cache_len=64,
                        algorithm="optimized_vfpc", pipeline_depth=2)
    out_q, recs_q = piped.generate(prompts, max_new_tokens=16, eos_id=eos_id)
    np.testing.assert_array_equal(out_p, out_q)
    waste_p = sum(r.wasted_tokens for r in recs_p)
    waste_q = sum(r.wasted_tokens for r in recs_q)
    assert waste_q >= waste_p


def test_ragged_prompts(served):
    """Continuous batching: right-padded ragged prompts decode correctly."""
    model, params, prompts = served
    lens = np.array([8, 5, 8, 3], np.int32)
    ragged = prompts.copy()
    for i, l in enumerate(lens):
        ragged[i, l:] = 0
    eng = ServeEngine(model, params, cache_len=64, algorithm="vfpc")
    out, _ = eng.generate(ragged, prompt_lens=lens, max_new_tokens=8, eos_id=-1)
    # row with full prompt must match the uniform-batch result
    eng2 = ServeEngine(model, params, cache_len=64, algorithm="vfpc")
    out2, _ = eng2.generate(prompts, max_new_tokens=8, eos_id=-1)
    np.testing.assert_array_equal(out[0], out2[0])
    np.testing.assert_array_equal(out[2], out2[2])
