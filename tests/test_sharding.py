"""Sharding rule resolution: divisibility fallback, candidate lists,
conflict avoidance, pod folding.  Pure logic — no multi-device needed."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.compat import make_mesh


@pytest.fixture(scope="module")
def mesh1d():
    return make_mesh((1,), ("data",))


def test_spec_basic(mesh1d):
    rules = sharding.make_rules()
    spec = sharding.spec_for(mesh1d, ("batch", "seq"), rules, (4, 16))
    assert spec == P("data", None)


def test_divisibility_fallback(mesh1d):
    # 1-device mesh: everything divides; use an abstract fake via shape checks
    rules = {"x": "data"}
    assert sharding.spec_for(mesh1d, ("x",), rules, (7,)) == P("data")  # 7 % 1 == 0


class FakeMesh:
    """Minimal mesh stand-in with controllable axis sizes."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_fallback_replicates_non_divisible():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = {"heads": "model", "embed": "data"}
    spec = sharding.spec_for(mesh, ("embed", "heads"), rules, (576, 9))
    assert spec == P("data", None)          # 9 heads can't shard 16 ways
    spec = sharding.spec_for(mesh, ("embed", "heads"), rules, (576, 48))
    assert spec == P("data", "model")


def test_candidate_list_prefers_first_divisible():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = {"b": [("data", "model"), "data"], "m": "model"}
    # 256 % 256 == 0 → both axes; then "m" conflicts on model → None
    spec = sharding.spec_for(mesh, ("b", None, "m"), rules, (256, 4096, 8192))
    assert spec == P(("data", "model"), None, None)
    # 32 % 256 != 0 → falls to "data"; "m" is free now
    spec = sharding.spec_for(mesh, ("b", None, "m"), rules, (32, 4096, 8192))
    assert spec == P("data", None, "model")


def test_conflict_avoidance():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = {"a": "model", "b": "model"}
    spec = sharding.spec_for(mesh, ("a", "b"), rules, (16, 16))
    assert spec == P("model", None)          # model already used by dim 0


def test_pod_folding():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = {"batch": "data", "mlp": "model"}
    spec = sharding.spec_for(mesh, ("batch", "mlp"), rules, (256, 512))
    assert spec == P(("pod", "data"), "model")


def test_long_context_profile():
    rules = sharding.make_rules("long_context")
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = sharding.spec_for(
        mesh, ("cache_batch", "kv_seq", "kv_heads", "head_dim"), rules,
        (1, 524288, 8, 128))
    assert spec == P(None, "data", None, None)


def test_unknown_axis_raises(mesh1d):
    with pytest.raises(KeyError):
        sharding.spec_for(mesh1d, ("nope",), {"x": None}, (4,))
