"""Data substrate: generator signatures, loader roundtrip, shard balancing,
token pipeline determinism."""

import numpy as np

from repro.data import (chess_like, dataset_by_name, dataset_stats,
                        ibm_generator, load_transactions, mushroom_like,
                        save_transactions)
from repro.core.bitset import pack_itemsets, popcount_rows
from repro.data.loader import balance_masks, balance_shards, shard_width_loads
from repro.data.tokens import TokenPipeline


def test_ibm_generator_signature():
    txns = ibm_generator(n_txns=500, n_items=100, avg_width=12, seed=1)
    stats = dataset_stats(txns, 100)
    assert stats["n_txns"] == 500
    assert 8 <= stats["avg_width"] <= 16
    assert all(all(0 <= i < 100 for i in t) for t in txns)


def test_chess_like_signature():
    txns, n_items = chess_like(n_txns=300)
    assert n_items == 75
    assert all(len(t) == 37 for t in txns)          # fixed width, like chess


def test_mushroom_like_signature():
    txns, n_items = mushroom_like(n_txns=300)
    assert n_items == 119
    assert all(len(t) == 23 for t in txns)


def test_dataset_by_name_scales():
    txns, n_items = dataset_by_name("c20d10k", scale=0.05)
    assert len(txns) == 500 and n_items == 192


def test_loader_roundtrip(tmp_path):
    txns, n_items = mushroom_like(n_txns=50)
    p = str(tmp_path / "t.txt")
    save_transactions(p, txns)
    loaded, n2 = load_transactions(p)
    assert loaded == [list(t) for t in txns]
    assert n2 <= n_items


def test_loader_roundtrip_blank_lines_and_whitespace(tmp_path):
    """FIMI files in the wild have blank lines and trailing whitespace; the
    loader must skip the former and tolerate the latter."""
    p = str(tmp_path / "messy.txt")
    with open(p, "w") as f:
        f.write("1 2 3   \n\n  \n7 5\n\t\n0\n   4 9\t\n\n")
    loaded, n_items = load_transactions(p)
    assert loaded == [[1, 2, 3], [7, 5], [0], [4, 9]]
    assert n_items == 10                         # max item 9 → catalog size 10


def test_loader_roundtrip_empty_file(tmp_path):
    p = str(tmp_path / "empty.txt")
    save_transactions(p, [])
    loaded, n_items = load_transactions(p)
    assert loaded == [] and n_items == 0


def test_dataset_stats_empty():
    """Empty transaction lists are routine on the stream path — zero stats,
    no ValueError from widths.max() and no NaN warning from widths.mean()."""
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        stats = dataset_stats([], 100)
    assert stats == {"n_txns": 0, "n_items": 100, "avg_width": 0.0,
                     "max_width": 0, "density": 0.0}


def test_balance_shards_by_width():
    rng = np.random.default_rng(0)
    txns = [list(range(rng.integers(1, 40))) for _ in range(200)]
    n_shards = 8
    balanced = balance_shards(txns, n_shards)
    loads = np.zeros(n_shards)
    for i, t in enumerate(balanced):
        loads[i % n_shards] += len(t)
    assert loads.max() / loads.min() < 1.25          # LPT keeps shards even
    assert sorted(map(tuple, balanced)) == sorted(map(tuple, txns))


def test_balance_masks_contiguous_split():
    """balance_masks matches scatter_db's *contiguous* split (the round-robin
    interleave of balance_shards never did): per-shard width loads even out,
    rows are a pure permutation, and the uneven tail shard is respected."""
    rng = np.random.default_rng(1)
    txns = [list(range(rng.integers(1, 40))) for _ in range(203)]  # 203 % 8 != 0
    masks = pack_itemsets(txns, 40)
    n_shards = 8
    skew_before = shard_width_loads(masks, n_shards)
    balanced = balance_masks(masks, n_shards)
    assert sorted(map(tuple, balanced.tolist())) == sorted(map(tuple, masks.tolist()))
    loads = shard_width_loads(balanced, n_shards)
    # the tail shard holds fewer real rows (203 → 26·7 + 21), so compare the
    # equal-sized shards and check the tail is no heavier than they are
    full = loads[:-1]
    assert full.max() / full.min() < 1.25
    assert loads[-1] <= full.max()
    assert full.max() - full.min() <= skew_before.max() - skew_before.min()
    # widths conserved
    assert loads.sum() == popcount_rows(masks).sum()


def test_shard_width_loads_matches_contiguous_slices():
    rng = np.random.default_rng(2)
    masks = pack_itemsets([list(range(rng.integers(1, 20)))
                           for _ in range(30)], 20)
    loads = shard_width_loads(masks, 4)
    per = 8   # ceil(30/4) with end padding
    expect = [popcount_rows(masks[i * per:(i + 1) * per]).sum()
              for i in range(4)]
    assert loads.tolist() == [float(x) for x in expect]


def test_token_pipeline_shapes_and_determinism():
    p1 = TokenPipeline(vocab_size=1000, seq_len=16, global_batch=4, seed=7)
    p2 = TokenPipeline(vocab_size=1000, seq_len=16, global_batch=4, seed=7)
    t1, l1 = p1.next_batch()
    t2, l2 = p2.next_batch()
    assert t1.shape == (4, 16) and (t1 == t2).all() and (l1 == l2).all()
    assert (t1[:, 1:] == l1[:, :-1]).all()          # labels are next tokens


def test_token_pipeline_sharding():
    full = TokenPipeline(vocab_size=1000, seq_len=8, global_batch=8, seed=3)
    s0 = TokenPipeline(vocab_size=1000, seq_len=8, global_batch=8, seed=3,
                       shard_index=0, shard_count=2)
    assert s0.local_batch == 4
    t, _ = s0.next_batch()
    assert t.shape == (4, 8)
