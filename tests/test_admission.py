"""SLO admission, fair shedding and result caching (DESIGN.md §12), proven
deterministically: scripted dispatch costs + virtual arrival clocks (the
``tests/loadgen.py`` harness), following ``test_autotune_plan.py``'s
scripted-timer discipline — no sleeps, no wall clock in any asserted number.
"""

import numpy as np
import pytest

from loadgen import arrivals, constant_cost, drive, make_ruleset
from repro.costmodel import CostController
from repro.costmodel.model import CostModel
from repro.serving import OpenLoopServer, RuleServeEngine, RuleStore
from test_rule_store import recs_key


@pytest.fixture(scope="module")
def setup():
    rules_a, baskets_a = make_ruleset(7)
    rules_b, baskets_b = make_ruleset(11, n_items=9, min_confidence=0.55)
    return rules_a, baskets_a, rules_b, baskets_b


def fresh_controller():
    return CostController(model=CostModel(persist=False))


def engine(rules, **kw):
    kw.setdefault("impl", "jnp")
    kw.setdefault("top_k", 3)
    kw.setdefault("autotune", False)
    return RuleServeEngine(rules, **kw)     # controller=None: the scripted
                                            # costs are the only calibration


# -- controller.should_admit ---------------------------------------------------


def test_should_admit_permissive_uncalibrated():
    ctrl = fresh_controller()
    admit, dec = ctrl.should_admit(work=1e9, latency_slo_s=1e-6)
    assert admit is True
    assert dec.site == "admission" and dec.predicted == {"slo": 1e-6}


def test_should_admit_thresholds_on_sojourn():
    ctrl = fresh_controller()
    key = ctrl.serve_key()
    for _ in range(3):
        ctrl.model.observe(key, 1000.0, 0.010)   # b = 1e-5 s/op exactly
    admit, dec = ctrl.should_admit(work=1000.0, latency_slo_s=0.020)
    assert admit is True
    admit, dec = ctrl.should_admit(work=1000.0, latency_slo_s=0.005)
    assert admit is False
    assert dec.predicted["sojourn"] > dec.predicted["slo"]
    # backlog counts toward the sojourn even when the dispatch itself fits
    admit, _ = ctrl.should_admit(work=1000.0, backlog_s=0.015,
                                 latency_slo_s=0.020)
    assert admit is False


# -- open-loop shedding --------------------------------------------------------


def test_no_shedding_under_light_load(setup):
    rules, baskets, _, _ = setup
    srv = OpenLoopServer(engine(rules), latency_slo_ms=20.0, batch=8,
                         max_wait_ms=5.0, cache_size=0,
                         controller=fresh_controller(),
                         dispatch_cost_fn=constant_cost(0.001))
    drive(srv, [baskets[i % 40] for i in range(30)],
          arrivals(20.0, 30, seed=1))          # 20 qps vs ~1ms dispatches
    s = srv.summary()
    assert s["shed"] == 0 and s["served"] == 30
    assert s["p99_ms"] <= 20.0


def test_shed_under_overload(setup):
    rules, baskets, _, _ = setup
    srv = OpenLoopServer(engine(rules), latency_slo_ms=15.0, batch=4,
                         max_wait_ms=5.0, cache_size=0,
                         controller=fresh_controller(),
                         dispatch_cost_fn=constant_cost(0.010))
    # 5000 qps offered vs 400 qps service: hopeless overload
    drive(srv, [baskets[i % 40] for i in range(60)],
          arrivals(5000.0, 60, seed=2))
    s = srv.summary()
    assert s["shed"] > 0 and s["shed_rate"] > 0.3
    # the first batch predates calibration and must have been admitted
    assert all(o.outcome != "shed" for o in srv.outcomes[:4])
    # every answer the server *did* give met the SLO-ish envelope: admitted
    # queries were only those whose predicted sojourn fit
    served = [o for o in srv.outcomes if o.outcome == "served"]
    assert served and max(o.latency_s for o in served) < 10.0  # not unbounded


def test_admission_permissive_until_calibrated(setup):
    rules, baskets, _, _ = setup
    ctrl = fresh_controller()
    srv = OpenLoopServer(engine(rules), latency_slo_ms=0.1, batch=4,
                         max_wait_ms=5.0, cache_size=0, controller=ctrl,
                         dispatch_cost_fn=constant_cost(1.0))
    t = arrivals(10000.0, 8, seed=3)
    for i in range(8):
        srv.submit(baskets[i], float(t[i]))
    # first 4 arrivals: no samples yet -> admitted (and they calibrate);
    # once the 1s dispatch cost is known, a 0.1ms SLO sheds everything
    assert [o.outcome != "shed" for o in srv.outcomes[:4]] == [True] * 4
    assert all(o.outcome == "shed" for o in srv.outcomes[4:])
    sites = [d.site for d in ctrl.decisions]
    assert "admission" in sites


def test_fair_shedding_protects_minor_tenant(setup):
    rules_a, baskets_a, rules_b, baskets_b = setup
    store = RuleStore(tenants={"hog": rules_a, "minor": rules_b})
    srv = OpenLoopServer(engine(store), latency_slo_ms=12.0, batch=4,
                         max_wait_ms=5.0, cache_size=0,
                         controller=fresh_controller(),
                         dispatch_cost_fn=constant_cost(0.010))
    t = arrivals(5000.0, 80, seed=4)
    for i in range(80):
        if i % 10 == 9:                       # 10% of traffic is "minor"
            srv.submit(baskets_b[i % 40], float(t[i]), tenant="minor")
        else:
            srv.submit(baskets_a[i % 40], float(t[i]), tenant="hog")
    srv.flush()
    s = srv.summary()["tenants"]
    assert s["hog"]["shed"] > 0                       # overload is real
    hog_rate = s["hog"]["shed"] / s["hog"]["offered"]
    minor_rate = s["minor"]["shed"] / s["minor"]["offered"]
    assert minor_rate < hog_rate                      # fairness held
    assert s["minor"]["answered"] > 0


def test_fair_shedding_off_sheds_arrivals_in_order(setup):
    rules_a, baskets_a, rules_b, baskets_b = setup
    store = RuleStore(tenants={"hog": rules_a, "minor": rules_b})
    srv = OpenLoopServer(engine(store), latency_slo_ms=12.0, batch=4,
                         max_wait_ms=5.0, cache_size=0, fair_shedding=False,
                         controller=fresh_controller(),
                         dispatch_cost_fn=constant_cost(0.010))
    t = arrivals(5000.0, 80, seed=4)
    for i in range(80):
        if i % 10 == 9:
            srv.submit(baskets_b[i % 40], float(t[i]), tenant="minor")
        else:
            srv.submit(baskets_a[i % 40], float(t[i]), tenant="hog")
    srv.flush()
    s = srv.summary()["tenants"]
    # without displacement the minor tenant sheds at ~the same rate
    assert s["minor"]["shed"] > 0


# -- result cache --------------------------------------------------------------


def test_cache_hit_bit_identical_and_skips_dispatch(setup):
    rules, baskets, _, _ = setup
    srv = OpenLoopServer(engine(rules), batch=1, cache_size=64,
                         dispatch_cost_fn=constant_cost(0.001))
    first = srv.submit(baskets[0], 0.0)
    assert first.outcome == "served" and srv.dispatches == 1
    hit = srv.submit(baskets[0], 1.0)
    assert hit.outcome == "cached" and srv.dispatches == 1   # no new dispatch
    assert hit.latency_s == 0.0
    assert recs_key(hit.results) == recs_key(first.results)
    # permuted/duplicated items are the same basket (set semantics)
    perm = list(reversed(baskets[0])) + [baskets[0][0]]
    assert srv.submit(perm, 2.0).outcome == "cached"


def test_cache_invalidated_by_swap_only_for_that_tenant(setup):
    rules_a, baskets_a, rules_b, baskets_b = setup
    rules_a2, _ = make_ruleset(23, n_items=16, min_confidence=0.7)
    store = RuleStore(tenants={"A": rules_a, "B": rules_b})
    eng = engine(store)
    srv = OpenLoopServer(eng, batch=1, cache_size=64,
                         dispatch_cost_fn=constant_cost(0.001))
    a0 = srv.submit(baskets_a[0], 0.0, tenant="A")
    b0 = srv.submit(baskets_b[0], 1.0, tenant="B")
    assert srv.submit(baskets_a[0], 2.0, tenant="A").outcome == "cached"
    assert srv.submit(baskets_b[0], 3.0, tenant="B").outcome == "cached"

    store.swap_rules("A", rules_a2)
    a1 = srv.submit(baskets_a[0], 4.0, tenant="A")
    assert a1.outcome == "served"                 # A's cache gone atomically
    want = RuleServeEngine(rules_a2, impl="jnp", top_k=3,
                           autotune=False).query([baskets_a[0]])[0]
    assert recs_key(a1.results) == recs_key(want)
    assert recs_key(a1.results) != recs_key(a0.results) or \
        len(a1.results) == len(a0.results) == 0
    b1 = srv.submit(baskets_b[0], 5.0, tenant="B")
    assert b1.outcome == "cached"                 # B's cache survived
    assert recs_key(b1.results) == recs_key(b0.results)


def test_cache_lru_eviction(setup):
    rules, baskets, _, _ = setup
    uniq: list = []
    for b in baskets:                             # three *distinct* baskets
        if tuple(b) not in {tuple(u) for u in uniq}:
            uniq.append(b)
        if len(uniq) == 3:
            break
    srv = OpenLoopServer(engine(rules), batch=1, cache_size=2,
                         dispatch_cost_fn=constant_cost(0.001))
    srv.submit(uniq[0], 0.0)
    srv.submit(uniq[1], 1.0)
    srv.submit(uniq[0], 2.0)                      # refresh 0 -> 1 is LRU
    srv.submit(uniq[2], 3.0)                      # evicts 1
    assert srv.submit(uniq[0], 4.0).outcome == "cached"
    assert srv.submit(uniq[1], 5.0).outcome == "served"


def test_cache_disabled(setup):
    rules, baskets, _, _ = setup
    srv = OpenLoopServer(engine(rules), batch=1, cache_size=0,
                         dispatch_cost_fn=constant_cost(0.001))
    srv.submit(baskets[0], 0.0)
    assert srv.submit(baskets[0], 1.0).outcome == "served"
    assert srv.dispatches == 2


# -- telemetry -----------------------------------------------------------------


def test_admission_decisions_carry_measured_latency(setup):
    rules, baskets, _, _ = setup
    ctrl = fresh_controller()
    srv = OpenLoopServer(engine(rules), latency_slo_ms=15.0, batch=4,
                         max_wait_ms=5.0, cache_size=0, controller=ctrl,
                         dispatch_cost_fn=constant_cost(0.010))
    drive(srv, [baskets[i % 40] for i in range(40)],
          arrivals(5000.0, 40, seed=5))
    rows = [d for d in ctrl.decision_rows() if d["site"] == "admission"]
    assert rows
    served_rows = [d for d in rows if d["chosen"] and d["measured"]]
    assert served_rows           # admitted queries backfilled real latency
    shed_rows = [d for d in rows if not d["chosen"]]
    assert shed_rows and all(d["measured"] == 0.0 for d in shed_rows)
    # served-outcome latencies reconcile with the decision backfills
    served_lat = sorted(o.latency_s for o in srv.outcomes
                        if o.outcome == "served" and o.seq >= 4)
    assert served_lat
    assert any(abs(d["measured"] - served_lat[-1]) < 1e-9
               for d in served_rows)


def test_outcome_as_dict_roundtrip(setup):
    rules, baskets, _, _ = setup
    srv = OpenLoopServer(engine(rules), batch=1, cache_size=4,
                         dispatch_cost_fn=constant_cost(0.002))
    srv.submit(baskets[0], 0.5)
    d = srv.outcomes[0].as_dict()
    assert d["outcome"] == "served" and d["tenant"] == "default"
    assert d["latency_ms"] == pytest.approx(
        srv.outcomes[0].latency_s * 1e3)
    assert set(d) == {"seq", "tenant", "t_arrival", "outcome", "latency_ms",
                      "dispatch_idx", "n_fused"}
