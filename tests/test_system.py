"""End-to-end behaviour tests for the paper's system.

The paper's central claims, verified on synthetic stand-in datasets:
 (1) every pass-combining algorithm produces EXACTLY the Apriori itemsets;
 (2) combined passes reduce the number of MapReduce jobs (dispatches);
 (3) skipped-pruning phases generate more candidates yet identical output;
 (4) straggler handling re-dispatches without corrupting results.
"""

import numpy as np
import pytest

from repro.core import ALGORITHMS, mine, sequential_apriori
from repro.data import dataset_by_name


@pytest.fixture(scope="module")
def mushroom_small():
    txns, n_items = dataset_by_name("mushroom", scale=0.04)  # 324 txns
    return txns, n_items


def test_end_to_end_all_algorithms(mushroom_small):
    txns, n_items = mushroom_small
    oracle = sequential_apriori(txns, 0.33)
    results = {}
    for algo in sorted(ALGORITHMS):
        res = mine(txns, n_items=n_items, min_sup=0.33, algorithm=algo)
        assert res.itemsets() == oracle, algo
        results[algo] = res
    # deep mining actually happened (dense dataset → itemsets of length ≥ 4)
    assert max(oracle) >= 4
    # pass combining reduces job count
    assert results["fpc"].dispatches < results["spc"].dispatches
    assert results["optimized_vfpc"].dispatches < results["spc"].dispatches


def test_skipped_pruning_effect(mushroom_small):
    """Optimized phases: more candidates, same answer."""
    txns, n_items = mushroom_small
    plain = mine(txns, n_items=n_items, min_sup=0.4, algorithm="vfpc")
    opt = mine(txns, n_items=n_items, min_sup=0.4, algorithm="optimized_vfpc")
    assert opt.itemsets() == plain.itemsets()
    multi_plain = [p for p in plain.phases if p.npass > 1]
    multi_opt = [p for p in opt.phases if p.npass > 1]
    assert multi_opt, "expected multi-pass phases at this min_sup"
    cands_plain = sum(sum(p.candidate_counts) for p in multi_plain)
    cands_opt = sum(sum(p.candidate_counts) for p in multi_opt)
    assert cands_opt >= cands_plain  # un-pruned candidates present


def test_c20d10k_ibm_dataset():
    txns, n_items = dataset_by_name("c20d10k", scale=0.05)
    oracle = sequential_apriori(txns, 0.2)
    res = mine(txns, n_items=n_items, min_sup=0.2, algorithm="optimized_etdpc")
    assert res.itemsets() == oracle


def test_straggler_speculative_redispatch(mushroom_small):
    """A pathologically slow counting job triggers one re-dispatch."""
    txns, n_items = mushroom_small
    res = mine(txns, n_items=n_items, min_sup=0.45, algorithm="spc",
               spec_factor=0.0)  # every phase counts as a straggler
    assert res.straggler_events >= 1
    oracle = sequential_apriori(txns, 0.45)
    assert res.itemsets() == oracle
