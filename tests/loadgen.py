"""Deterministic open-loop load generation for serving tests (DESIGN.md §12).

Tier-1 latency/shedding assertions must be exact, so nothing here touches the
wall clock: arrivals are synthetic timestamps from a seeded generator,
dispatch costs are scripted functions, and the only "clock" is
:class:`repro.obs.clock.FakeClock` — virtual time that moves when the test
says so (re-exported here for convenience; the tracer, ``time_once`` and the
:class:`~repro.serving.admission.OpenLoopServer` all accept the same clock
object, DESIGN.md §13).  The server consumes these directly (its latency
math is closed over submitted timestamps + scripted costs), so a load test
is a pure function of its seed.
"""

from __future__ import annotations

import numpy as np

from repro.core import generate_ruleset, mine
from repro.obs.clock import FakeClock

__all__ = ["FakeClock", "make_ruleset", "arrivals", "tenant_mix",
           "constant_cost", "per_query_cost", "drive"]


def make_ruleset(seed: int, n_items: int = 12, n_txns: int = 120,
                 min_sup: float = 0.3, min_confidence: float = 0.6):
    """Small mined RuleSet + realistic query baskets from a seeded synthetic
    transaction stream (three overlapping base patterns plus noise — the
    same generator shape the engine tests use)."""
    rng = np.random.default_rng(seed)
    base = rng.random((3, n_items)) < 0.5
    txns = []
    for _ in range(n_txns):
        pat = base[rng.integers(3)]
        row = np.where(rng.random(n_items) < 0.85, pat,
                       rng.random(n_items) < 0.1)
        txns.append(np.nonzero(row)[0].tolist() or [0])
    res = mine(txns, n_items=n_items, min_sup=min_sup)
    rules = generate_ruleset(res, min_confidence=min_confidence)
    baskets = [sorted(set(t[:-1])) or [0] for t in txns]
    return rules, baskets


def arrivals(rate_qps: float, n: int, seed: int = 0,
             jitter: float = 0.3) -> np.ndarray:
    """``n`` non-decreasing arrival timestamps at mean ``rate_qps``.

    Deterministic in the seed; ``jitter`` spreads the inter-arrival gaps
    uniformly in ``[1∓jitter]/rate`` so batching sees realistic clumping
    without a wall clock anywhere.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.uniform(1.0 - jitter, 1.0 + jitter, n) / float(rate_qps)
    return np.cumsum(gaps)


def tenant_mix(tenants, n: int, seed: int = 0, weights=None) -> list:
    """Seeded tenant label per query (optionally skewed — fair-shedding
    tests want one tenant hogging the stream)."""
    rng = np.random.default_rng(seed)
    if weights is not None:
        w = np.asarray(weights, np.float64)
        p = w / w.sum()
    else:
        p = None
    return [tenants[i] for i in rng.choice(len(tenants), n, p=p)]


def constant_cost(seconds: float):
    """Scripted dispatch-cost function: every dispatch takes ``seconds``."""
    return lambda n_queries, work: float(seconds)


def per_query_cost(seconds_each: float, overhead: float = 0.0):
    """Scripted cost linear in dispatch size: ``overhead + n·seconds_each``
    (affine like the cost model's own fits, so scripted calibration is
    self-consistent)."""
    return lambda n_queries, work: float(overhead + n_queries * seconds_each)


def drive(server, baskets, times, tenants=None) -> None:
    """Feed one pre-generated arrival schedule through an OpenLoopServer:
    ``baskets[i]`` arrives at ``times[i]`` (under ``tenants[i]``), then the
    queue is drained."""
    for i, (b, t) in enumerate(zip(baskets, times)):
        if tenants is None:
            server.submit(b, float(t))
        else:
            server.submit(b, float(t), tenant=tenants[i])
    server.flush()
