"""Observability layer (DESIGN.md §13): deterministic span trees under
FakeClock (exact start/duration assertions, no sleeps), metrics-snapshot
schema golden tests (changing fields requires a schema-version bump),
disabled-tracer no-op guards, and Perfetto/Chrome-trace JSON validity
(required keys ``ph``/``ts``/``pid``/``tid``)."""

import json
import time

import numpy as np
import pytest

from loadgen import arrivals, constant_cost, drive, make_ruleset, tenant_mix
from repro.costmodel import CostController
from repro.costmodel.controller import Decision
from repro.costmodel.measure import time_once
from repro.costmodel.model import CostModel
from repro.obs import (NULL_TRACER, FakeClock, MonotonicClock, Registry,
                       Tracer, current_tracer, get_registry, set_registry,
                       use_tracer, validate_snapshot)
from repro.obs.metrics import (HISTOGRAM_FIELDS, SCHEMA_VERSION,
                               TOP_LEVEL_FIELDS)
from repro.obs.trace import NullTracer, set_tracer
from repro.obs.validate import main as validate_main
from repro.serving import OpenLoopServer, RuleServeEngine


@pytest.fixture()
def fresh_registry():
    """Swap in an empty process-wide registry; restore the old one after."""
    prev = get_registry()
    reg = set_registry(Registry())
    yield reg
    set_registry(prev)


# -- spans under FakeClock: exact, no sleeps -----------------------------------


def test_span_tree_exact_times():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("root", algo="vfpc") as root:
        clk.advance(1.0)
        with tr.span("child_a") as a:
            clk.advance(0.25)
        with tr.span("child_b", k=2) as b:
            clk.advance(0.5)
            b.event("midpoint")
        clk.advance(0.25)
    assert (root.t0, root.duration) == (0.0, 2.0)
    assert (a.t0, a.duration) == (1.0, 0.25)
    assert (b.t0, b.duration) == (1.25, 0.5)
    assert root.attrs["algo"] == "vfpc" and b.attrs["k"] == 2
    (ev,) = tr.events
    assert ev["name"] == "midpoint" and ev["ts"] == 1.75


def test_span_set_and_manual_close():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    s = tr.span("manual")
    clk.advance(3.0)
    s.set(result=7).close()
    s.close()                       # idempotent: t1 stays at first close
    assert s.duration == 3.0 and s.attrs["result"] == 7
    assert tr.current() is None


def test_nested_current_span_stack():
    tr = Tracer(clock=FakeClock())
    with tr.span("outer") as outer:
        assert tr.current() is outer
        with tr.span("inner") as inner:
            assert tr.current() is inner
        assert tr.current() is outer
    assert tr.current() is None


def test_add_span_virtual_track():
    tr = Tracer(clock=FakeClock())
    s = tr.add_span("serve.query", 1.0, 3.5, tid="queries",
                    tenant="t0", outcome="served")
    assert s.duration == 2.5 and s.tid == "queries"


# -- Chrome-trace/Perfetto export ----------------------------------------------


def test_chrome_export_required_keys(tmp_path):
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("root"):
        clk.advance(2.0)
        tr.event("decision.pass_width", args={"chosen": 2})
    tr.add_span("q", 0.5, 1.5, tid="queries")
    path = tmp_path / "trace.json"
    tr.export(str(path))
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert events, "export produced no events"
    for e in events:
        assert {"ph", "pid", "tid"} <= set(e), e
        if e["ph"] in ("X", "i"):
            assert "ts" in e and "name" in e, e
    xs = {e["name"]: e for e in events if e["ph"] == "X"}
    assert xs["root"]["dur"] == pytest.approx(2e6)     # µs
    assert xs["q"]["dur"] == pytest.approx(1e6)
    inst = [e for e in events if e["ph"] == "i"]
    assert inst and inst[0]["args"]["chosen"] == 2
    # thread-name metadata maps tid ints back to track names
    names = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert {"main", "queries"} <= names


def test_chrome_export_normalizes_per_track():
    """Wall-clock and virtual-time tracks each start at ts=0."""
    clk = FakeClock(t0=1000.0)
    tr = Tracer(clock=clk)
    with tr.span("wall"):
        clk.advance(1.0)
    tr.add_span("virt", 2.0, 3.0, tid="queries")
    xs = {e["name"]: e for e in tr.to_chrome()["traceEvents"]
          if e["ph"] == "X"}
    assert xs["wall"]["ts"] == 0.0
    assert xs["virt"]["ts"] == 0.0


def test_chrome_export_closes_open_spans():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    tr.span("leaked")
    clk.advance(4.0)
    (x,) = [e for e in tr.to_chrome()["traceEvents"] if e["ph"] == "X"]
    assert x["dur"] == pytest.approx(4e6)


def test_export_coerces_numpy_attrs(tmp_path):
    tr = Tracer(clock=FakeClock())
    tr.add_span("s", 0.0, 1.0, n=np.int64(3), frac=np.float32(0.5),
                arr=np.arange(2))
    path = tmp_path / "t.json"
    tr.export(str(path))
    args = [e for e in json.loads(path.read_text())["traceEvents"]
            if e["ph"] == "X"][0]["args"]
    assert args["n"] == 3.0 and args["frac"] == 0.5
    assert isinstance(args["arr"], str)    # non-scalar falls back to repr


# -- disabled-tracer fast path -------------------------------------------------


def test_null_tracer_is_default_and_singleton():
    assert current_tracer() is NULL_TRACER
    assert not NULL_TRACER.enabled
    s1 = NULL_TRACER.span("a", k=1)
    s2 = NULL_TRACER.span("b")
    assert s1 is s2                  # one shared null span, no allocation
    assert s1.set(x=1) is s1
    with s1 as s:
        s.event("ignored")
    assert NULL_TRACER.add_span("v", 0.0, 1.0) is s1
    assert NULL_TRACER.event("e") is None
    assert NULL_TRACER.current() is None


def test_null_tracer_overhead_guard():
    """Disabled tracing must stay O(dict build + dispatch) per call site —
    a very loose wall-time ceiling guards against accidental recording."""
    t0 = time.perf_counter()
    for _ in range(20_000):
        with current_tracer().span("hot", k=3, n=100):
            pass
    elapsed = time.perf_counter() - t0
    assert elapsed < 1.0, f"null span path too slow: {elapsed:.3f}s / 20k"
    assert NULL_TRACER.spans == [] and NULL_TRACER.events == []


def test_use_tracer_scoping():
    tr = Tracer(clock=FakeClock())
    with use_tracer(tr):
        assert current_tracer() is tr
        with use_tracer(None):
            assert current_tracer() is NULL_TRACER
        assert current_tracer() is tr
    assert current_tracer() is NULL_TRACER
    set_tracer(tr)
    assert current_tracer() is tr
    set_tracer(None)
    assert current_tracer() is NULL_TRACER


# -- metrics registry + versioned snapshot schema ------------------------------


def test_registry_counters_gauges_histograms():
    reg = Registry()
    reg.counter("serving.offered", tenant="t0").inc()
    reg.counter("serving.offered", tenant="t0").inc(2)
    reg.counter("serving.offered", tenant="t1").inc()
    reg.gauge("serving.qps").set(1234.5)
    h = reg.histogram("serving.latency_ms", tenant="t0")
    for v in (0.2, 0.4, 3.0):
        h.observe(v)
    assert reg.value("serving.offered", tenant="t0") == 3
    assert reg.value("serving.offered", tenant="t1") == 1
    assert reg.value("no.such.metric") == 0.0
    snap = reg.snapshot()
    assert snap["counters"]["serving.offered{tenant=t0}"] == 3
    assert snap["gauges"]["serving.qps"] == 1234.5
    hs = snap["histograms"]["serving.latency_ms{tenant=t0}"]
    assert hs["count"] == 3 and hs["sum"] == pytest.approx(3.6)
    assert validate_snapshot(snap) == []


def test_histogram_percentiles_bucket_accurate():
    reg = Registry()
    h = reg.histogram("lat")
    for _ in range(98):
        h.observe(0.8)               # → 1.0 ms bucket
    h.observe(40.0)                  # → 50 ms bucket
    h.observe(200.0)                 # → 250 ms bucket
    assert h.percentile(50) == 1.0
    assert h.percentile(99) in (50.0, 250.0)
    assert h.percentile(100) == 250.0


def test_snapshot_schema_golden():
    """Schema v1 golden: these exact field sets ARE the versioned contract.
    If this test fails, bump ``repro.obs.metrics.SCHEMA_VERSION`` (and
    teach ``validate_snapshot`` the new version) instead of editing the
    assertion."""
    assert SCHEMA_VERSION == 1
    assert TOP_LEVEL_FIELDS == ("schema_version", "counters", "gauges",
                                "histograms")
    assert HISTOGRAM_FIELDS == ("buckets", "counts", "count", "sum",
                                "p50", "p99")
    reg = Registry()
    reg.counter("c").inc()
    reg.gauge("g").set(1)
    reg.histogram("h").observe(1.0)
    snap = reg.snapshot()
    assert set(snap) == set(TOP_LEVEL_FIELDS)
    assert set(snap["histograms"]["h"]) == set(HISTOGRAM_FIELDS)
    assert len(snap["histograms"]["h"]["counts"]) == \
        len(snap["histograms"]["h"]["buckets"]) + 1


def test_validate_snapshot_rejects_drift():
    good = Registry().snapshot()
    assert validate_snapshot(good) == []
    assert validate_snapshot([]) != []
    assert validate_snapshot({}) != []
    bad_version = dict(good, schema_version=99)
    assert any("schema_version" in e for e in validate_snapshot(bad_version))
    extra = dict(good, surprise=1)
    assert any("bump SCHEMA_VERSION" in e for e in validate_snapshot(extra))
    bad_counter = dict(good, counters={"c": "NaN-ish"})
    assert validate_snapshot(bad_counter) != []
    bad_hist = dict(good, histograms={"h": {"buckets": [], "counts": []}})
    assert validate_snapshot(bad_hist) != []


def test_validate_cli(tmp_path, capsys):
    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps(Registry().snapshot()))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema_version": 42}))
    assert validate_main([str(ok)]) == 0
    assert validate_main([str(bad)]) == 1
    assert validate_main([str(ok), str(bad)]) == 1
    out = capsys.readouterr().out
    assert "ok (schema v1" in out and "INVALID" in out


# -- clock unification (satellite: one injectable clock everywhere) ------------


def test_monotonic_clock_contract():
    clk = MonotonicClock()
    a, b = clk.now(), clk.now()
    assert b >= a


def test_time_once_accepts_fake_clock():
    clk = FakeClock()
    cost = time_once(lambda: clk.advance(0.01) and None, reps=3, clock=clk)
    assert cost == pytest.approx(0.01)


def test_loadgen_reexports_obs_fakeclock():
    import loadgen
    from repro.obs.clock import FakeClock as ObsFakeClock
    assert loadgen.FakeClock is ObsFakeClock


# -- cost-controller decision events with residual backfill --------------------


def test_decision_event_carries_residual():
    tr = Tracer(clock=FakeClock())
    ctrl = CostController(model=CostModel(persist=False))
    with use_tracer(tr):
        dec = ctrl._record(Decision("pass_width", "k", {"2": 1.0}, 2))
    (ev,) = tr.events
    assert ev["name"] == "decision.pass_width"
    assert ev["args"]["predicted_chosen"] == 1.0
    assert "measured" in ev["args"] and ev["args"]["measured"] is None
    dec.measured = 1.5          # observe_* backfill path
    assert ev["args"]["measured"] == 1.5
    assert ev["args"]["residual"] == pytest.approx(0.5)


def test_decisions_counted_in_registry(fresh_registry):
    ctrl = CostController(model=CostModel(persist=False))
    ctrl.should_admit(work=1.0, latency_slo_s=1.0)
    ctrl.should_admit(work=1.0, latency_slo_s=1.0)
    assert fresh_registry.value("costmodel.decisions", site="admission") == 2


def test_decision_without_tracer_has_no_trace_args():
    ctrl = CostController(model=CostModel(persist=False))
    dec = ctrl._record(Decision("pass_width", "k", {"2": 1.0}, 2))
    assert dec.trace_args is None
    dec.measured = 2.0          # must not blow up with tracing off
    assert dec.as_dict()["measured"] == 2.0
    assert "trace_args" not in dec.as_dict()


# -- traced mining: spans account for the run's wall-clock ---------------------


def _tiny_txns(seed=0, n=60, n_items=10):
    rng = np.random.default_rng(seed)
    return [sorted(set(rng.integers(0, n_items,
                                    rng.integers(2, 6)).tolist()))
            for _ in range(n)]


def test_traced_mine_span_taxonomy_and_wallclock(fresh_registry):
    from repro.core import mine
    tr = Tracer()
    with use_tracer(tr):
        res = mine(_tiny_txns(), n_items=10, min_sup=0.2)
    names = {s.name for s in tr.spans}
    assert {"mine.run", "mine.scatter", "mine.phase",
            "mine.gen", "mine.count"} <= names
    (run,) = [s for s in tr.spans if s.name == "mine.run"]
    phases = [s for s in tr.spans if s.name == "mine.phase"]
    assert len(phases) == res.n_phases
    # the run span and the reported wall-clock are the same boundaries
    assert run.duration == pytest.approx(res.total_seconds, rel=0.05)
    # per-level phase spans sum (within tolerance) to the run's wall-clock:
    # the gap is scatter + controller bookkeeping between phases
    phase_sum = sum(p.duration for p in phases)
    assert phase_sum <= run.duration * 1.001
    assert phase_sum >= 0.5 * run.duration
    # count spans carry the roofline achieved-vs-peak attributes (§10)
    counts = [s for s in tr.spans if s.name == "mine.count"]
    assert counts
    for c in counts:
        assert 0.0 < c.attrs["roofline_peak_frac"] <= 1.0
        assert c.attrs["roofline_bound"] in ("compute", "memory")
    # registry mirrored the RuntimeStats increments 1:1
    assert fresh_registry.value("mine.dispatches") == res.dispatches
    assert fresh_registry.value("mine.compiles") == res.compiles
    snap = fresh_registry.snapshot()
    assert snap["gauges"]["mine.total_seconds"] == res.total_seconds
    assert validate_snapshot(snap) == []


def test_untraced_mine_records_nothing(fresh_registry):
    from repro.core import mine
    assert current_tracer() is NULL_TRACER
    res = mine(_tiny_txns(1), n_items=10, min_sup=0.2)
    assert res.n_phases >= 1
    assert NULL_TRACER.spans == [] and NULL_TRACER.events == []


def test_traced_stream_miner_spans():
    from repro.stream import StreamMiner
    tr = Tracer()
    with use_tracer(tr):
        miner = StreamMiner(10, 0.3, capacity=64, refresh_rules=True)
        miner.push(_tiny_txns(2, n=48))
        miner.push(_tiny_txns(3, n=16))
    names = [s.name for s in tr.spans]
    assert "stream.update" in names and "stream.remine" in names
    updates = [s for s in tr.spans if s.name == "stream.update"]
    assert [u.attrs["path"] for u in updates] == \
        [u.path for u in miner.updates]
    for u in updates:
        assert u.t1 is not None and u.attrs["window"] == u.attrs["window"]


# -- traced serving: per-query admission→dispatch spans + tenant histograms ----


@pytest.fixture(scope="module")
def ruleset():
    return make_ruleset(7)


def test_open_loop_server_feeds_registry_and_trace(ruleset):
    rules, baskets = ruleset
    from repro.serving import RuleStore
    store = RuleStore(tenants={"t0": rules, "t1": rules})
    eng = RuleServeEngine(store, impl="jnp", top_k=3, autotune=False)
    ctrl = CostController(model=CostModel(persist=False))
    reg = Registry()
    tr = Tracer(clock=FakeClock())
    n = 60
    times = arrivals(50.0, n, seed=3)          # light load: nothing sheds
    tenants = tenant_mix(["t0", "t1"], n, seed=4, weights=[4, 1])
    with use_tracer(tr):
        srv = OpenLoopServer(eng, latency_slo_ms=20.0, batch=8,
                             max_wait_ms=5.0, cache_size=32, controller=ctrl,
                             dispatch_cost_fn=constant_cost(0.001),
                             registry=reg, clock=FakeClock())
        drive(srv, [baskets[i % 10] for i in range(n)],   # repeats → cache hits
              times, tenants)
    s = srv.summary()
    assert s["n_queries"] == n
    # per-tenant offered/admitted/shed counters reconcile with the summary
    offered = sum(reg.value("serving.offered", tenant=t)
                  for t in ("t0", "t1"))
    assert offered == n
    shed = sum(reg.value("serving.shed", tenant=t) for t in ("t0", "t1"))
    assert shed == s["shed"]
    # per-tenant latency histograms cover every answered query
    snap = reg.snapshot()
    assert validate_snapshot(snap) == []
    answered = sum(h["count"] for k, h in snap["histograms"].items()
                   if k.startswith("serving.latency_ms"))
    assert answered == s["served"] + s["cached"]
    # virtual-time trace: one serve.query span per submitted query,
    # dispatch spans on their own device track
    qspans = [sp for sp in tr.spans if sp.name == "serve.query"]
    assert len(qspans) == n
    outcomes = {sp.attrs["seq"]: sp.attrs["outcome"] for sp in qspans}
    for o in srv.outcomes:
        assert outcomes[o.seq] == o.outcome
    served_spans = [sp for sp in qspans if sp.attrs["outcome"] == "served"]
    for sp in served_spans:
        assert sp.duration > 0 and sp.attrs["queue_wait_ms"] >= 0
    dspans = [sp for sp in tr.spans if sp.name == "serve.dispatch"]
    assert len(dspans) == s["dispatches"]
    assert all(sp.tid == "device" for sp in dspans)
    # headline gauges landed in the registry
    assert reg.value("serving.qps") > 0
    assert reg.value("serving.shed_rate") == pytest.approx(s["shed_rate"])


def test_cache_counters_back_compat(ruleset):
    from repro.serving.admission import ResultCache
    cache = ResultCache(capacity=4)
    assert cache.get("t", 0, [1, 2], 3) is None
    cache.put("t", 0, [1, 2], 3, ["r"])
    assert cache.get("t", 0, [1, 2], 3) == ["r"]
    assert (cache.hits, cache.misses) == (1, 1)
    assert isinstance(cache.hits, int)


# -- report.py --trace rendering -----------------------------------------------


def test_report_trace_tables(tmp_path, capsys):
    from repro.launch.report import (load_trace, report_trace, trace_spans)
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("mine.run"):
        clk.advance(0.1)
        with tr.span("mine.phase"):
            clk.advance(0.8)
        clk.advance(0.1)
    path = tmp_path / "trace.json"
    tr.export(str(path))
    spans = trace_spans(load_trace(str(path)))
    by_name = {s["name"]: s for s in spans}
    # self time subtracts nested spans on the same track
    assert by_name["mine.run"]["dur"] == pytest.approx(1e6)
    assert by_name["mine.run"]["self_us"] == pytest.approx(0.2e6)
    assert by_name["mine.phase"]["self_us"] == pytest.approx(0.8e6)
    report_trace(str(path), top=5)
    out = capsys.readouterr().out
    assert "slowest spans" in out and "mine.phase" in out
    assert "Per-phase time breakdown" in out


def test_report_decisions_accepts_stream_payload(tmp_path, capsys):
    from repro.launch.report import load_decisions, report_decisions
    rows = [{"site": "remine", "key": "k", "chosen": True,
             "predicted": {"remine": 0.5}, "measured": 0.6}]
    stream_shaped = tmp_path / "stream.json"
    stream_shaped.write_text(json.dumps(
        {"updates_per_s": 10.0, "paths": {"delta": 3}, "decisions": rows}))
    assert load_decisions(str(stream_shaped)) == rows
    report_decisions(str(stream_shaped))
    assert "remine" in capsys.readouterr().out
    # a payload without decisions degrades to a hint, not a crash
    legacy = tmp_path / "legacy.json"
    legacy.write_text(json.dumps({"updates_per_s": 10.0}))
    assert load_decisions(str(legacy)) == []
    report_decisions(str(legacy))
    assert "no decision rows" in capsys.readouterr().out
