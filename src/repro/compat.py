"""Version shims over JAX API drift.

The runtime targets both current JAX (``jax.shard_map``, ``jax.make_mesh``
with ``axis_types``, ``check_vma``) and the 0.4.x line still common on
clusters (``jax.experimental.shard_map.shard_map`` with ``check_rep``, no
``jax.sharding.AxisType``).  Everything that builds meshes or shard_maps goes
through here so the rest of the codebase is version-agnostic.
"""

from __future__ import annotations

import jax

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_TOP_LEVEL_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` on new JAX, the experimental one on 0.4.x.

    ``check_vma`` maps onto the old ``check_rep`` flag (same semantics for our
    usage: skip the replication/varying-manual-axes check).
    """
    if _HAS_TOP_LEVEL_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
