"""Transaction-file IO and shard balancing.

File format: one transaction per line, space-separated item ids (the standard
FIMI repository format the paper's datasets use).
"""

from __future__ import annotations

import heapq

import numpy as np

from repro.core.bitset import pack_itemsets, popcount_rows


def save_transactions(path: str, transactions) -> None:
    with open(path, "w") as f:
        for t in transactions:
            f.write(" ".join(str(i) for i in t) + "\n")


def load_transactions(path: str) -> tuple[list[list[int]], int]:
    """Load FIMI-format transactions. Returns (transactions, n_items)."""
    txns = []
    max_item = -1
    with open(path) as f:
        for line in f:
            row = [int(x) for x in line.split()]
            if row:
                txns.append(row)
                max_item = max(max_item, max(row))
    return txns, max_item + 1


def dataset_stats(transactions, n_items: int) -> dict:
    if len(transactions) == 0:
        # streaming windows are routinely empty; zero stats, no NaN/ValueError
        return {"n_txns": 0, "n_items": n_items, "avg_width": 0.0,
                "max_width": 0, "density": 0.0}
    widths = np.array([len(t) for t in transactions])
    return {
        "n_txns": len(transactions),
        "n_items": n_items,
        "avg_width": float(widths.mean()),
        "max_width": int(widths.max()),
        "density": float(widths.mean() / n_items) if n_items else 0.0,
    }


def balance_shards(transactions, n_shards: int) -> list[list[int]]:
    """Static straggler mitigation: order transactions so that per-shard total
    width (≈ per-mapper work) is balanced under round-robin sharding.

    Greedy LPT assignment by width, then interleave shards back into a single
    ordering whose round-robin split reproduces the balanced assignment.
    """
    order = np.argsort([-len(t) for t in transactions], kind="stable")
    loads = np.zeros(n_shards, dtype=np.int64)
    shards: list[list[int]] = [[] for _ in range(n_shards)]
    for idx in order:
        s = int(np.argmin(loads))
        shards[s].append(int(idx))
        loads[s] += len(transactions[idx])
    # interleave: row-major over (position, shard) — round-robin recovers shards
    out = []
    maxlen = max(len(s) for s in shards)
    for pos in range(maxlen):
        for s in range(n_shards):
            if pos < len(shards[s]):
                out.append(transactions[shards[s][pos]])
    return out


def _contiguous_shard_sizes(n: int, n_shards: int) -> list[int]:
    """Real-row counts per shard of ``scatter_db``'s contiguous equal split:
    rows are padded to the shard multiple at the *end*, so every shard holds
    ``ceil(n/d)`` rows and only the tail shards see the zero padding."""
    per = (n + (-n) % n_shards) // n_shards
    return [max(0, min(per, n - s * per)) for s in range(n_shards)]


def shard_width_loads(db_masks: np.ndarray, n_shards: int) -> np.ndarray:
    """Per-shard total transaction width under the contiguous equal split
    ``scatter_db`` produces — the straggler-skew input the cost controller
    prices against the rebalance cost (DESIGN.md §11)."""
    n = db_masks.shape[0]
    if n_shards <= 1 or n == 0:
        return np.array([float(popcount_rows(db_masks).sum())] if n else [0.0])
    per = (n + (-n) % n_shards) // n_shards
    w = popcount_rows(db_masks).astype(np.float64)
    pad = per * n_shards - n
    if pad:
        w = np.concatenate([w, np.zeros(pad)])
    return w.reshape(n_shards, per).sum(axis=1)


def balance_masks(db_masks: np.ndarray, n_shards: int) -> np.ndarray:
    """Reorder packed transactions so the *contiguous* equal split has
    balanced per-shard total width (capacity-constrained LPT).

    Unlike :func:`balance_shards` (which interleaves for a round-robin
    split), this matches how ``MapReduceRuntime.scatter_db`` actually
    shards: contiguous blocks of ``ceil(n/d)`` rows.  Each shard's capacity
    is its real-row count under that split (the zero padding shrinks only
    the tail shards), so the permutation is exact — counting is a sum over
    transactions, so the mining result is bit-identical either way.
    """
    n = db_masks.shape[0]
    if n_shards <= 1 or n <= n_shards:
        return db_masks
    caps = _contiguous_shard_sizes(n, n_shards)
    widths = popcount_rows(db_masks).astype(np.int64)
    order = np.argsort(-widths, kind="stable")
    counts = [0] * n_shards
    assign = np.empty(n, np.int32)
    heap = [(0.0, s) for s in range(n_shards) if caps[s] > 0]
    heapq.heapify(heap)
    for i in order:
        load, s = heapq.heappop(heap)   # least-loaded shard with room
        assign[i] = s
        counts[s] += 1
        if counts[s] < caps[s]:
            heapq.heappush(heap, (load + float(widths[i]), s))
    perm = np.argsort(assign, kind="stable")
    return db_masks[perm]


def pack_dataset(transactions, n_items: int) -> np.ndarray:
    """Pack to (N, W) uint32 bitmask matrix."""
    return pack_itemsets([list(t) for t in transactions], n_items)
