"""Transaction-file IO and shard balancing.

File format: one transaction per line, space-separated item ids (the standard
FIMI repository format the paper's datasets use).
"""

from __future__ import annotations

import numpy as np

from repro.core.bitset import pack_itemsets


def save_transactions(path: str, transactions) -> None:
    with open(path, "w") as f:
        for t in transactions:
            f.write(" ".join(str(i) for i in t) + "\n")


def load_transactions(path: str) -> tuple[list[list[int]], int]:
    """Load FIMI-format transactions. Returns (transactions, n_items)."""
    txns = []
    max_item = -1
    with open(path) as f:
        for line in f:
            row = [int(x) for x in line.split()]
            if row:
                txns.append(row)
                max_item = max(max_item, max(row))
    return txns, max_item + 1


def dataset_stats(transactions, n_items: int) -> dict:
    if len(transactions) == 0:
        # streaming windows are routinely empty; zero stats, no NaN/ValueError
        return {"n_txns": 0, "n_items": n_items, "avg_width": 0.0,
                "max_width": 0, "density": 0.0}
    widths = np.array([len(t) for t in transactions])
    return {
        "n_txns": len(transactions),
        "n_items": n_items,
        "avg_width": float(widths.mean()),
        "max_width": int(widths.max()),
        "density": float(widths.mean() / n_items) if n_items else 0.0,
    }


def balance_shards(transactions, n_shards: int) -> list[list[int]]:
    """Static straggler mitigation: order transactions so that per-shard total
    width (≈ per-mapper work) is balanced under round-robin sharding.

    Greedy LPT assignment by width, then interleave shards back into a single
    ordering whose round-robin split reproduces the balanced assignment.
    """
    order = np.argsort([-len(t) for t in transactions], kind="stable")
    loads = np.zeros(n_shards, dtype=np.int64)
    shards: list[list[int]] = [[] for _ in range(n_shards)]
    for idx in order:
        s = int(np.argmin(loads))
        shards[s].append(int(idx))
        loads[s] += len(transactions[idx])
    # interleave: row-major over (position, shard) — round-robin recovers shards
    out = []
    maxlen = max(len(s) for s in shards)
    for pos in range(maxlen):
        for s in range(n_shards):
            if pos < len(shards[s]):
                out.append(transactions[shards[s][pos]])
    return out


def pack_dataset(transactions, n_items: int) -> np.ndarray:
    """Pack to (N, W) uint32 bitmask matrix."""
    return pack_itemsets([list(t) for t in transactions], n_items)
