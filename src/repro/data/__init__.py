"""Data substrate: transaction datasets (paper) and LM token pipeline (framework)."""

from .generator import ibm_generator, chess_like, mushroom_like, dataset_by_name
from .loader import load_transactions, save_transactions, dataset_stats

__all__ = [
    "ibm_generator", "chess_like", "mushroom_like", "dataset_by_name",
    "load_transactions", "save_transactions", "dataset_stats",
]
