"""Synthetic transaction datasets.

The paper evaluates on ``c20d10k`` (IBM Quest generator: 10 000 txns, 192 items,
avg width 20), ``chess`` (3 196 txns, 75 items, fixed width 37) and ``mushroom``
(8 124 txns, 119 items, width 23).  The two UCI datasets are not redistributable
offline, so :func:`chess_like` / :func:`mushroom_like` synthesize attribute–value
datasets with the same (N, |I|, w) signature and a similar density profile
(skewed per-attribute value distributions → long frequent itemsets at moderate
min_sup, which is the regime the paper's optimizations target).
"""

from __future__ import annotations

import numpy as np


def ibm_generator(n_txns: int = 10_000, n_items: int = 192, avg_width: int = 20,
                  n_patterns: int = 40, avg_pattern_len: float = 4.0,
                  corruption: float = 0.25, seed: int = 0) -> list[list[int]]:
    """IBM-Quest-style generator (T{avg_width}D{n_txns} over ``n_items`` items).

    Maximal potential itemsets ("patterns") are drawn with exponentially skewed
    popularity; each transaction fills its Poisson-sized width from patterns,
    dropping items with ``corruption`` probability, topping up with noise.
    """
    rng = np.random.default_rng(seed)
    # patterns: sizes ~ 1 + Poisson, items share overlap with the previous one
    patterns = []
    prev: np.ndarray | None = None
    for _ in range(n_patterns):
        size = max(2, 1 + rng.poisson(avg_pattern_len - 1))
        if prev is not None and prev.size and rng.random() < 0.5:
            n_keep = min(prev.size, max(1, int(rng.random() * size)))
            keep = rng.choice(prev, size=n_keep, replace=False)
        else:
            keep = np.empty(0, dtype=np.int64)
        fresh = rng.choice(n_items, size=size, replace=False)
        pat = np.unique(np.concatenate([keep, fresh]))[:size]
        patterns.append(pat)
        prev = pat
    weights = rng.exponential(1.0, n_patterns)
    weights /= weights.sum()

    txns = []
    for _ in range(n_txns):
        width = max(1, rng.poisson(avg_width))
        items: set[int] = set()
        guard = 0
        while len(items) < width and guard < 40:
            guard += 1
            pat = patterns[rng.choice(n_patterns, p=weights)]
            kept = pat[rng.random(pat.size) >= corruption]
            items.update(int(i) for i in kept)
        if len(items) > width:
            items = set(list(items)[:width])
        while len(items) < width:  # top up with uniform noise
            items.add(int(rng.integers(n_items)))
        txns.append(sorted(items))
    return txns


def _attribute_value_dataset(n_txns: int, value_counts: list[int],
                             skew: float, seed: int) -> tuple[list[list[int]], int]:
    """One item per (attribute, value); each txn takes one value per attribute.

    ``skew`` is the Zipf-ish exponent of the per-attribute value distribution —
    higher skew → denser dataset → longer frequent itemsets.
    """
    rng = np.random.default_rng(seed)
    offsets = np.concatenate([[0], np.cumsum(value_counts)])[:-1]
    txns = []
    probs = []
    for vc in value_counts:
        p = 1.0 / np.arange(1, vc + 1) ** skew
        probs.append(p / p.sum())
    for _ in range(n_txns):
        row = [int(off + rng.choice(vc, p=p))
               for off, vc, p in zip(offsets, value_counts, probs)]
        txns.append(sorted(row))
    return txns, int(sum(value_counts))


def chess_like(n_txns: int = 3196, seed: int = 0) -> tuple[list[list[int]], int]:
    """chess stand-in: 37 attributes / 75 items / width exactly 37 (dense)."""
    # 36 binary-ish attributes + one multi-valued (real chess: 36 features + class)
    value_counts = [2] * 35 + [3, 2]  # 35*2 + 3 + 2 = 75 items, 37 attributes
    return _attribute_value_dataset(n_txns, value_counts, skew=2.2, seed=seed)


def mushroom_like(n_txns: int = 8124, seed: int = 0) -> tuple[list[list[int]], int]:
    """mushroom stand-in: 23 attributes / 119 items / width exactly 23."""
    # 22 attributes with 2–10 values + class(2): 23 attributes, 119 items
    value_counts = [2, 6, 4, 10, 2, 9, 4, 3, 10, 2, 5, 4, 4, 9, 9, 4, 3, 5, 9, 6, 5, 2]
    assert sum(value_counts) == 119 - 2
    value_counts = value_counts + [2]
    return _attribute_value_dataset(n_txns, value_counts, skew=1.8, seed=seed)


def dataset_by_name(name: str, seed: int = 0, scale: float = 1.0):
    """Named datasets used across benchmarks. Returns (transactions, n_items)."""
    if name == "c20d10k":
        n = int(10_000 * scale)
        return ibm_generator(n_txns=n, n_items=192, avg_width=20, seed=seed), 192
    if name == "c20d200k":  # the paper's speedup dataset (c20d10k × 20)
        n = int(200_000 * scale)
        return ibm_generator(n_txns=n, n_items=192, avg_width=20, seed=seed), 192
    if name == "chess":
        t, n_items = chess_like(n_txns=int(3196 * scale), seed=seed)
        return t, n_items
    if name == "mushroom":
        t, n_items = mushroom_like(n_txns=int(8124 * scale), seed=seed)
        return t, n_items
    raise ValueError(f"unknown dataset {name!r}")
