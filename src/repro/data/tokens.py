"""Synthetic LM token pipeline for the training substrate.

Deterministic, dependency-free corpus: a Zipf unigram distribution modulated by
an order-1 Markov structure so that a model can actually reduce loss.  The
iterator yields fixed-shape (tokens, labels) batches suitable for pjit — the
host-side analogue of a tf.data/grain pipeline, with shard-aware slicing for
multi-host use.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_streams: int = 64          # markov "topics"
    shard_index: int = 0         # this host's data shard
    shard_count: int = 1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 4096)  # active vocabulary head
        base = 1.0 / np.arange(1, v + 1) ** 1.1
        self._base = base / base.sum()
        self._v = v
        # per-stream multiplicative tilt, fixed across steps
        self._tilts = rng.random((self.n_streams, v)) ** 2
        self._step = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.shard_count == 0
        return self.global_batch // self.shard_count

    def next_batch(self) -> tuple[np.ndarray, np.ndarray]:
        """Returns (tokens, labels) of shape (local_batch, seq_len) int32."""
        rng = np.random.default_rng(
            (self.seed, self._step, self.shard_index))
        self._step += 1
        b, s = self.local_batch, self.seq_len
        streams = rng.integers(self.n_streams, size=b)
        toks = np.empty((b, s + 1), dtype=np.int32)
        for i, st in enumerate(streams):
            p = self._base * self._tilts[st]
            p = p / p.sum()
            toks[i] = rng.choice(self._v, size=s + 1, p=p)
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self):
        while True:
            yield self.next_batch()
