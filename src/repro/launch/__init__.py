# launcher package: mesh.py, dryrun.py, train.py, serve.py, mine.py
