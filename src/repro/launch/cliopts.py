"""Shared CLI plumbing for policy / cost-model hyperparameters.

Every launch CLI that picks a pass-combining algorithm exposes the same knob
set (the paper's β thresholds, the measured policy's width ceiling, the
serving latency budget) through :func:`add_policy_args`, and
:func:`policy_kwargs_from_args` filters the provided values down to what the
selected policy's constructor actually accepts — ``--beta1`` silently applies
to ETDPC and is dropped for SPC, so one flag vocabulary serves all eight
algorithms without per-CLI special cases.
"""

from __future__ import annotations

import argparse
import inspect

from repro.core.policy import ALGORITHMS

# CLI flag (dest) → Policy-constructor kwarg
_POLICY_DESTS = {
    "time_scale": "time_scale",
    "beta": "beta",
    "beta1": "beta1",
    "beta2": "beta2",
    "alpha_fast": "alpha_fast",
    "fpc_npass": "npass",
    "max_width": "max_width",
}


def add_policy_args(ap: argparse.ArgumentParser) -> None:
    """Attach the uniform policy/controller hyperparameter group.

    All default to None = "use the policy's own default"; only explicitly
    set flags reach the constructor.
    """
    g = ap.add_argument_group(
        "policy hyperparameters",
        "apply to whichever --algorithm is selected; flags a policy does "
        "not accept are ignored (DESIGN.md §9)")
    g.add_argument("--time-scale", type=float, default=None,
                   help="β-threshold rescale for DPC/ETDPC/measured "
                        "(paper seconds → this runtime; default 1e-3)")
    g.add_argument("--beta", type=float, default=None,
                   help="DPC absolute elapsed-time threshold (paper: 60s)")
    g.add_argument("--beta1", type=float, default=None,
                   help="ETDPC first threshold (paper: 40s)")
    g.add_argument("--beta2", type=float, default=None,
                   help="ETDPC second threshold (paper: 60s)")
    g.add_argument("--alpha-fast", type=float, default=None,
                   help="DPC fast-phase candidate-budget multiplier")
    g.add_argument("--fpc-npass", type=int, default=None,
                   help="FPC fixed pass width")
    g.add_argument("--max-width", type=int, default=None,
                   help="measured policy: widest phase the cost model may "
                        "pick")
    g.add_argument("--latency-budget-ms", type=float, default=None,
                   help="measured serving fusion: per-dispatch latency "
                        "budget (unset = fuse maximally)")


def policy_kwargs_from_args(args: argparse.Namespace,
                            algorithm: str) -> dict:
    """The subset of set flags the ``algorithm``'s Policy accepts."""
    policy_cls, _ = ALGORITHMS[algorithm]
    accepted = inspect.signature(policy_cls.__init__).parameters
    out = {}
    for dest, kwarg in _POLICY_DESTS.items():
        val = getattr(args, dest, None)
        if val is not None and kwarg in accepted:
            out[kwarg] = val
    return out
