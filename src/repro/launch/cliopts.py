"""Shared CLI plumbing for policy / cost-model hyperparameters.

Every launch CLI that picks a pass-combining algorithm exposes the same knob
set (the paper's β thresholds, the measured policy's width ceiling, the
serving latency budget) through :func:`add_policy_args`, and
:func:`policy_kwargs_from_args` filters the provided values down to what the
selected policy's constructor actually accepts — ``--beta1`` silently applies
to ETDPC and is dropped for SPC, so one flag vocabulary serves all eight
algorithms without per-CLI special cases.
"""

from __future__ import annotations

import argparse
import inspect

from repro.core.policy import ALGORITHMS

# CLI flag (dest) → Policy-constructor kwarg
_POLICY_DESTS = {
    "time_scale": "time_scale",
    "beta": "beta",
    "beta1": "beta1",
    "beta2": "beta2",
    "alpha_fast": "alpha_fast",
    "fpc_npass": "npass",
    "max_width": "max_width",
}


def add_policy_args(ap: argparse.ArgumentParser) -> None:
    """Attach the uniform policy/controller hyperparameter group.

    All default to None = "use the policy's own default"; only explicitly
    set flags reach the constructor.
    """
    g = ap.add_argument_group(
        "policy hyperparameters",
        "apply to whichever --algorithm is selected; flags a policy does "
        "not accept are ignored (DESIGN.md §9)")
    g.add_argument("--time-scale", type=float, default=None,
                   help="β-threshold rescale for DPC/ETDPC/measured "
                        "(paper seconds → this runtime; default 1e-3)")
    g.add_argument("--beta", type=float, default=None,
                   help="DPC absolute elapsed-time threshold (paper: 60s)")
    g.add_argument("--beta1", type=float, default=None,
                   help="ETDPC first threshold (paper: 40s)")
    g.add_argument("--beta2", type=float, default=None,
                   help="ETDPC second threshold (paper: 60s)")
    g.add_argument("--alpha-fast", type=float, default=None,
                   help="DPC fast-phase candidate-budget multiplier")
    g.add_argument("--fpc-npass", type=int, default=None,
                   help="FPC fixed pass width")
    g.add_argument("--max-width", type=int, default=None,
                   help="measured policy: widest phase the cost model may "
                        "pick")
    g.add_argument("--latency-budget-ms", type=float, default=None,
                   help="measured serving fusion: per-dispatch latency "
                        "budget (unset = fuse maximally)")


def policy_kwargs_from_args(args: argparse.Namespace,
                            algorithm: str) -> dict:
    """The subset of set flags the ``algorithm``'s Policy accepts."""
    policy_cls, _ = ALGORITHMS[algorithm]
    accepted = inspect.signature(policy_cls.__init__).parameters
    out = {}
    for dest, kwarg in _POLICY_DESTS.items():
        val = getattr(args, dest, None)
        if val is not None and kwarg in accepted:
            out[kwarg] = val
    return out


def add_serving_args(ap: argparse.ArgumentParser) -> None:
    """Attach the multi-tenant / SLO serving knob group (DESIGN.md §12)."""
    g = ap.add_argument_group(
        "multi-tenant serving",
        "tenant registry, SLO admission and result caching (DESIGN.md §12)")
    g.add_argument("--tenants", type=int, default=1,
                   help="serve N tenants through one packed arena (the "
                        "transaction stream is round-robin split and mined "
                        "per tenant; 1 = single-tenant, PR 5 layout)")
    g.add_argument("--rate-qps", type=float, default=None,
                   help="open-loop mode: offer queries at this rate against "
                        "a virtual arrival clock and report sustained "
                        "qps / p99 / shed rate (unset = closed-loop replay)")
    g.add_argument("--latency-slo-ms", type=float, default=None,
                   help="admission target: shed queries whose predicted "
                        "sojourn (backlog + dispatch) misses this SLO")
    g.add_argument("--cache-size", type=int, default=256,
                   help="LRU result-cache entries (0 disables caching)")
    g.add_argument("--max-wait-ms", type=float, default=5.0,
                   help="open-loop: dispatch a partial batch once its oldest "
                        "query has waited this long")
    g.add_argument("--no-fair-shedding", action="store_true",
                   help="shed arrivals in order instead of displacing "
                        "over-share tenants' queued queries")


def add_obs_args(ap: argparse.ArgumentParser) -> None:
    """Attach the unified observability flag group (DESIGN.md §13)."""
    g = ap.add_argument_group(
        "observability",
        "unified tracing + metrics (DESIGN.md §13); disabled flags cost "
        "nothing (no-op span fast path)")
    g.add_argument("--trace-out", default=None,
                   help="write a Chrome-trace-event JSON of this run "
                        "(open in ui.perfetto.dev, or render with "
                        "`python -m repro.launch.report --trace`)")
    g.add_argument("--metrics-out", default=None,
                   help="write the versioned metrics-registry snapshot "
                        "(validate with `python -m repro.obs.validate`)")


def tracer_from_args(args: argparse.Namespace):
    """Install (and return) a live tracer when ``--trace-out`` was given;
    otherwise leave the zero-overhead NULL_TRACER active."""
    from repro.obs.trace import NULL_TRACER, Tracer, set_tracer
    if getattr(args, "trace_out", None):
        return set_tracer(Tracer())
    return NULL_TRACER


def write_obs_outputs(args: argparse.Namespace, tracer=None) -> None:
    """Flush ``--trace-out`` / ``--metrics-out`` files at the end of a run."""
    import json

    if getattr(args, "trace_out", None) and tracer is not None \
            and tracer.enabled:
        tracer.export(args.trace_out)
        print(f"trace: {len(tracer.spans)} spans + {len(tracer.events)} "
              f"events -> {args.trace_out} (open in ui.perfetto.dev)")
    if getattr(args, "metrics_out", None):
        from repro.obs.metrics import get_registry
        snap = get_registry().snapshot()
        with open(args.metrics_out, "w") as f:
            json.dump(snap, f, indent=2)
        n = (len(snap["counters"]) + len(snap["gauges"])
             + len(snap["histograms"]))
        print(f"metrics: {n} series (schema v{snap['schema_version']}) "
              f"-> {args.metrics_out}")


def add_mesh_args(ap: argparse.ArgumentParser) -> None:
    """Attach the uniform mesh / distributed-launch knob group (§11).

    The same flags drive single-process multi-device runs (simulated via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``) and true
    multi-host runs (every worker passes identical flags; the coordinator
    triple may instead come from JAX_COORDINATOR_ADDRESS / JAX_NUM_PROCESSES
    / JAX_PROCESS_ID env vars).
    """
    g = ap.add_argument_group(
        "mesh / distributed",
        "2-D (data, cand) mining mesh + elastic repartitioning "
        "(DESIGN.md §11)")
    g.add_argument("--n-data-shards", type=int, default=None,
                   help="transaction shards (default: devices / cand shards)")
    g.add_argument("--n-cand-shards", type=int, default=1,
                   help="candidate shards (2-D decomposition; 1 replicates "
                        "candidates as in the paper)")
    g.add_argument("--no-elastic", action="store_true",
                   help="pin the initial mesh split (skip per-level "
                        "cost-model repartitioning)")
    g.add_argument("--max-retries", type=int, default=2,
                   help="per-phase counting-job retries after a shard "
                        "failure (rescatter + re-dispatch)")
    g.add_argument("--balance-shards", choices=("auto", "on", "off"),
                   default="auto",
                   help="LPT width-balance the transaction shards: 'auto' "
                        "lets the cost model enable it when predicted "
                        "straggler waste exceeds the re-pack cost")
    g.add_argument("--coordinator", default=None,
                   help="host:port of process 0 for jax.distributed "
                        "multi-host init (unset = single-process)")
    g.add_argument("--num-processes", type=int, default=None,
                   help="total jax.distributed processes")
    g.add_argument("--process-id", type=int, default=None,
                   help="this worker's jax.distributed process index")


def runtime_from_args(args: argparse.Namespace, impl: str | None = None):
    """Build the (runtime, extra mine() kwargs) the mesh flags describe.

    Calls :func:`repro.launch.mesh.init_distributed` first (no-op without a
    coordinator), then lays the 2-D mining mesh over every device the
    process can now see.
    """
    from repro.core.mapreduce import MapReduceRuntime
    from repro.launch.mesh import init_distributed, make_mining_mesh

    init_distributed(getattr(args, "coordinator", None),
                     getattr(args, "num_processes", None),
                     getattr(args, "process_id", None))
    n_cand = getattr(args, "n_cand_shards", 1) or 1
    mesh = make_mining_mesh(getattr(args, "n_data_shards", None), n_cand)
    runtime = MapReduceRuntime(
        mesh=mesh, impl=impl, cand_axis="cand" if n_cand > 1 else None)
    balance = {"auto": None, "on": True, "off": False}[
        getattr(args, "balance_shards", "auto")]
    mine_kwargs = dict(elastic=not getattr(args, "no_elastic", False),
                       max_retries=getattr(args, "max_retries", 2),
                       balance_shards_by_width=balance)
    return runtime, mine_kwargs
