"""CLI: train an assigned architecture (reduced or full config).

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 50 --seq-len 128 --batch 8 --algorithm vfpc --ckpt ckpt/
"""

from __future__ import annotations

import argparse

import jax

from repro.core.policy import ALGORITHMS
from repro.data.tokens import TokenPipeline
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import TrainLoop, init_train_state, restore_elastic
from repro.train.loop import state_shardings
from repro import sharding


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--algorithm", default="vfpc", choices=sorted(ALGORITHMS),
                    help="fused-phase width policy (paper technique)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--mesh", action="store_true",
                    help="shard over all local devices")
    args = ap.parse_args()

    model = build_model(args.arch, smoke=args.smoke)
    cfg = model.cfg
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                         global_batch=args.batch)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps, compress=args.compress_grads)
    mesh = rules = None
    if args.mesh:
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh()
        rules = sharding.make_rules()

    state = None
    if args.ckpt:
        tmpl = jax.eval_shape(
            lambda k: init_train_state(model, opt, k), jax.random.PRNGKey(0))
        if mesh is not None:
            state, step = restore_elastic(args.ckpt, model, opt, mesh, rules, tmpl)
        else:
            from repro.train import load_checkpoint
            tree, step = load_checkpoint(args.ckpt, template=tmpl)
            state = jax.device_put(tree) if tree is not None else None
        if state is not None:
            print(f"resumed from step {step}")
    if state is None:
        state = init_train_state(model, opt, jax.random.PRNGKey(0), mesh, rules)

    loop = TrainLoop(model, pipe, opt, algorithm=args.algorithm,
                     mesh=mesh, rules=rules, checkpoint_dir=args.ckpt)
    state, records = loop.run(state, args.steps)
    for r in records:
        print(f"phase {r.phase_idx:3d} npass={r.npass} steps={r.steps} "
              f"loss={r.mean_loss:.4f} {r.elapsed:.2f}s")
    print(f"final loss {records[-1].mean_loss:.4f} over {len(records)} phases "
          f"({sum(r.npass for r in records)} steps)")


if __name__ == "__main__":
    main()
