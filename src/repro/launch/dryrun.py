import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on the
single-pod 16×16 mesh and the 2×16×16 two-pod mesh, and record memory /
cost / collective analysis for the roofline report.

The XLA_FLAGS line above MUST stay the first statement — jax locks the device
count on first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun.jsonl [--skip-existing]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs import ARCH_IDS, SHAPES, cell_is_runnable, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.model import ShardCtx
from repro.optim import AdamWConfig, adamw
from repro.roofline import parse_collectives, roofline_terms
from repro.train.loop import make_train_step


def _batch_shardings(model, shape, mesh, rules, specs):
    in_axes = model.input_axes(shape)
    return jax.tree.map(
        lambda ax, s: sharding.sharding_for(mesh, ax, rules, s.shape),
        in_axes, specs, is_leaf=lambda x: isinstance(x, tuple))


def build_step(model, shape, mesh, rules):
    """Returns (fn, example_inputs, in_shardings, out_shardings, donate)."""
    ctx = ShardCtx(mesh, rules)
    cfg = model.cfg
    p_shapes, p_axes = model.abstract_params()
    psh = sharding.tree_shardings(mesh, p_axes, rules, p_shapes)
    specs = model.input_specs(shape)

    if shape.kind == "train":
        opt = AdamWConfig()
        fn = make_train_step(model, opt, mesh, rules, npass=1)
        state_abs = jax.eval_shape(
            lambda: {"params": p_shapes, "opt": adamw.init_state(p_shapes, opt)})
        batch_abs = {k: jax.ShapeDtypeStruct((1,) + v.shape, v.dtype)
                     for k, v in specs.items()}
        return fn, (state_abs, batch_abs), None, None  # shardings inside fn

    if shape.kind == "prefill":
        cache_shapes = jax.eval_shape(
            lambda: model.empty_caches(shape.global_batch, shape.seq_len))
        csh = sharding.tree_shardings(mesh, model.cache_axes(), rules, cache_shapes)
        bsh = _batch_shardings(model, shape, mesh, rules, specs)

        def prefill_fn(params, batch):
            return model.prefill(params, batch, shape.seq_len, ctx)

        jfn = jax.jit(prefill_fn, in_shardings=(psh, bsh),
                      out_shardings=(None, csh))
        return jfn, (p_shapes, specs), None, None

    # decode: one serve step (new token given a seq_len KV cache)
    cache_shapes = jax.eval_shape(
        lambda: model.empty_caches(shape.global_batch, shape.seq_len))
    csh = sharding.tree_shardings(mesh, model.cache_axes(), rules, cache_shapes)
    in_specs = {"caches": cache_shapes,
                "token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)}

    from repro.models.model import sharded_greedy

    def serve_step(params, caches, token, pos):
        logits, new_caches = model.decode_step(params, caches, token, pos, ctx)
        nxt = sharded_greedy(logits, ctx)[:, None]
        return nxt, new_caches

    jfn = jax.jit(serve_step,
                  in_shardings=(psh, csh, None, None),
                  out_shardings=(None, csh),
                  donate_argnums=(1,))
    return jfn, (p_shapes, in_specs["caches"], in_specs["token"],
                 in_specs["pos"]), None, None


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             profile: str = "auto") -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False,
           "profile": profile}
    runnable, why = cell_is_runnable(arch, shape_name)
    if not runnable:
        rec["skipped"] = why
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.size
        if profile == "auto":
            profile = "long_context" if shape_name == "long_500k" else "default"
            rec["profile"] = profile
        rules = sharding.make_rules(profile)
        model = build_model(cfg)
        t0 = time.perf_counter()
        fn, ex, _, _ = build_step(model, shape, mesh, rules)
        lowered = fn.lower(*ex)
        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        t0 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t0, 2)

        ma = compiled.memory_analysis()
        rec["temp_bytes_per_dev"] = int(ma.temp_size_in_bytes)
        rec["arg_bytes_per_dev"] = int(ma.argument_size_in_bytes)
        rec["out_bytes_per_dev"] = int(ma.output_size_in_bytes)
        ca = compiled.cost_analysis() or {}
        rec["hlo_flops_raw"] = float(ca.get("flops", 0.0))
        rec["hlo_bytes_raw"] = float(ca.get("bytes accessed", 0.0))

        coll = parse_collectives(compiled.as_text(), chips)
        rec["collectives_by_op"] = {k: int(v) for k, v in coll["by_op"].items()}
        rec["collective_per_chip_bytes"] = int(coll["per_chip_bytes"])

        terms = roofline_terms(cfg, shape, chips, coll["per_chip_bytes"],
                               rec["hlo_flops_raw"])
        rec["roofline"] = terms.as_dict()
        rec["ok"] = True
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--profile", default="auto",
                    choices=["auto", "default", "decode", "long_context"],
                    help="sharding rules profile (perf iterations)")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok") or r.get("skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                key = (arch, shape_name, "2x16x16" if mp else "16x16")
                if key in done:
                    continue
                rec = run_cell(arch, shape_name, mp, profile=args.profile)
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                if rec.get("skipped"):
                    n_skip += 1
                    print(f"SKIP {key}: {rec['skipped']}", flush=True)
                elif rec["ok"]:
                    n_ok += 1
                    r = rec["roofline"]
                    print(f"OK   {key}: compile={rec['compile_s']}s "
                          f"temp={rec['temp_bytes_per_dev']/2**30:.1f}GiB "
                          f"terms(c/m/n)={r['compute_s']:.3e}/{r['memory_s']:.3e}/"
                          f"{r['collective_s']:.3e} dom={r['dominant']}", flush=True)
                else:
                    n_fail += 1
                    print(f"FAIL {key}: {rec['error']}", flush=True)
    print(f"done: ok={n_ok} fail={n_fail} skip={n_skip}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
