"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun.jsonl.

  PYTHONPATH=src python -m repro.launch.report results/dryrun.jsonl
"""

from __future__ import annotations

import json
import sys
from collections import defaultdict


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def fmt_s(x):
    return f"{x:.3e}"


def load(path):
    rows = [json.loads(l) for l in open(path)]
    dedup = {}
    for r in rows:  # last write wins per cell
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return dedup


def dryrun_table(cells) -> str:
    out = ["| arch | shape | mesh | status | compile s | temp GiB/dev | "
           "args GiB/dev | HLO GFLOPs (raw) | collectives (per-chip MB) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(cells.items()):
        if r.get("skipped"):
            out.append(f"| {arch} | {shape} | {mesh} | SKIP (full-attn) | – | – | – | – | – |")
            continue
        if not r.get("ok"):
            out.append(f"| {arch} | {shape} | {mesh} | **FAIL** | – | – | – | – | – |")
            continue
        coll = ", ".join(f"{k}:{v/2**20:.0f}" for k, v in
                         sorted(r["collectives_by_op"].items()))
        out.append(
            f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']} | "
            f"{fmt_bytes(r['temp_bytes_per_dev'])} | "
            f"{fmt_bytes(r['arg_bytes_per_dev'])} | "
            f"{r['hlo_flops_raw']/1e9:.1f} | {coll or '—'} |")
    return "\n".join(out)


def roofline_table(cells) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL_FLOPS | useful ratio | bound by |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(cells.items()):
        if mesh != "16x16" or not r.get("ok"):
            continue
        t = r["roofline"]
        bound = {"compute": "MXU/VPU", "memory": "HBM bw",
                 "collective": "ICI"}[t["dominant"]]
        out.append(
            f"| {arch} | {shape} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{t['dominant']}** | {t['model_flops']:.2e} | "
            f"{t['useful_ratio']:.2f} | {bound} |")
    return "\n".join(out)


def pick_hillclimb(cells):
    """worst roofline balance, most collective-bound, most paper-representative."""
    live = {k: v for k, v in cells.items()
            if k[2] == "16x16" and v.get("ok")}
    def frac(r):
        t = r["roofline"]
        dom = max(t["compute_s"], t["memory_s"], t["collective_s"])
        return t["compute_s"] / dom if dom else 0.0
    worst = min(live.items(), key=lambda kv: frac(kv[1]))
    coll = max(live.items(), key=lambda kv: (
        kv[1]["roofline"]["collective_s"]
        / max(kv[1]["roofline"]["compute_s"], 1e-12)))
    return worst[0], coll[0]


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl"
    cells = load(path)
    n_ok = sum(1 for r in cells.values() if r.get("ok"))
    n_skip = sum(1 for r in cells.values() if r.get("skipped"))
    n_fail = len(cells) - n_ok - n_skip
    print(f"## Dry-run status: {n_ok} ok / {n_skip} skipped / {n_fail} failed "
          f"({len(cells)} cells)\n")
    print(dryrun_table(cells))
    print()
    print("## Roofline (single-pod 16×16)\n")
    print(roofline_table(cells))
    print()
    worst, coll = pick_hillclimb(cells)
    print(f"hillclimb candidates: worst-fraction={worst}, most-collective={coll}")


if __name__ == "__main__":
    main()
