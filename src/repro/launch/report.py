"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun.jsonl —
with ``--decisions``, the cost-model §Decisions table (DESIGN.md §9) — and
with ``--trace``, the top-slowest-spans + per-phase breakdown of a
``--trace-out`` file (DESIGN.md §13).

  PYTHONPATH=src python -m repro.launch.report results/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.report --decisions results/decisions.jsonl
  PYTHONPATH=src python -m repro.launch.report --trace trace.json

``--decisions`` accepts a jsonl of decision rows or any of the CLIs'
``--json-out`` files (``mine``, ``stream``, ``serve_rules``).
"""

from __future__ import annotations

import argparse
import json
from collections import defaultdict


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def fmt_s(x):
    return f"{x:.3e}"


def load(path):
    rows = [json.loads(l) for l in open(path)]
    dedup = {}
    for r in rows:  # last write wins per cell
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return dedup


def dryrun_table(cells) -> str:
    out = ["| arch | shape | mesh | status | compile s | temp GiB/dev | "
           "args GiB/dev | HLO GFLOPs (raw) | collectives (per-chip MB) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(cells.items()):
        if r.get("skipped"):
            out.append(f"| {arch} | {shape} | {mesh} | SKIP (full-attn) | – | – | – | – | – |")
            continue
        if not r.get("ok"):
            out.append(f"| {arch} | {shape} | {mesh} | **FAIL** | – | – | – | – | – |")
            continue
        coll = ", ".join(f"{k}:{v/2**20:.0f}" for k, v in
                         sorted(r["collectives_by_op"].items()))
        out.append(
            f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']} | "
            f"{fmt_bytes(r['temp_bytes_per_dev'])} | "
            f"{fmt_bytes(r['arg_bytes_per_dev'])} | "
            f"{r['hlo_flops_raw']/1e9:.1f} | {coll or '—'} |")
    return "\n".join(out)


def roofline_table(cells) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL_FLOPS | useful ratio | bound by |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(cells.items()):
        if mesh != "16x16" or not r.get("ok"):
            continue
        t = r["roofline"]
        bound = {"compute": "MXU/VPU", "memory": "HBM bw",
                 "collective": "ICI"}[t["dominant"]]
        out.append(
            f"| {arch} | {shape} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{t['dominant']}** | {t['model_flops']:.2e} | "
            f"{t['useful_ratio']:.2f} | {bound} |")
    return "\n".join(out)


def pick_hillclimb(cells):
    """worst roofline balance, most collective-bound, most paper-representative."""
    live = {k: v for k, v in cells.items()
            if k[2] == "16x16" and v.get("ok")}
    def frac(r):
        t = r["roofline"]
        dom = max(t["compute_s"], t["memory_s"], t["collective_s"])
        return t["compute_s"] / dom if dom else 0.0
    worst = min(live.items(), key=lambda kv: frac(kv[1]))
    coll = max(live.items(), key=lambda kv: (
        kv[1]["roofline"]["collective_s"]
        / max(kv[1]["roofline"]["compute_s"], 1e-12)))
    return worst[0], coll[0]


def decision_table(rows) -> str:
    """Per-decision telemetry (CostController.decision_rows dicts): one line
    per adaptive decision — what the model predicted, what was chosen, what
    was then measured, and the prediction error where both are known."""
    out = ["| site | model key | predicted (s) | chosen | measured s | rel err |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        pred = r.get("predicted") or {}
        pred_s = ", ".join(f"{k}:{v:.2e}" for k, v in sorted(pred.items()))
        chosen, measured = r.get("chosen"), r.get("measured")
        err = "–"
        p_chosen = pred.get(str(chosen))
        if p_chosen is not None and measured:
            err = f"{abs(p_chosen - measured) / measured:.2f}"
        m_s = fmt_s(measured) if measured is not None else "–"
        out.append(f"| {r.get('site')} | {r.get('key')} | {pred_s or '—'} | "
                   f"{chosen} | {m_s} | {err} |")
    return "\n".join(out)


def decision_summary(rows) -> str:
    by_site: dict = defaultdict(list)
    for r in rows:
        p = (r.get("predicted") or {}).get(str(r.get("chosen")))
        if p is not None and r.get("measured"):
            by_site[r.get("site")].append(
                abs(p - r["measured"]) / r["measured"])
    lines = [f"{len(rows)} decisions recorded"]
    for site, errs in sorted(by_site.items()):
        lines.append(f"  {site}: {len(errs)} measured, "
                     f"mean |rel err| {sum(errs)/len(errs):.2f}")
    return "\n".join(lines)


def load_decisions(path) -> list:
    """Decision rows from a jsonl stream, a bare JSON list, or any JSON
    object with a ``decisions`` list (e.g. ``launch.mine --json-out``)."""
    text = open(path).read()
    try:
        doc = json.loads(text)
    except ValueError:
        return [json.loads(l) for l in text.splitlines() if l.strip()]
    return doc.get("decisions", []) if isinstance(doc, dict) else doc


def outcome_table(summary: dict) -> str:
    """Admission-telemetry roll-up (``serving.outcome_summary`` dict): the
    overall served/cached/shed split plus the per-tenant fairness view."""
    lines = [
        f"{summary.get('n_queries', 0)} queries: "
        f"{summary.get('served', 0)} served, "
        f"{summary.get('cached', 0)} cached, "
        f"{summary.get('shed', 0)} shed "
        f"(shed rate {summary.get('shed_rate', 0.0):.1%}, "
        f"cache hit rate {summary.get('cache_hit_rate', 0.0):.1%}); "
        f"answered p50 {summary.get('p50_ms', 0.0):.2f} ms / "
        f"p99 {summary.get('p99_ms', 0.0):.2f} ms",
        "", "| tenant | offered | answered | shed | shed rate |",
        "|---|---|---|---|---|"]
    for tenant, row in sorted((summary.get("tenants") or {}).items()):
        rate = row["shed"] / row["offered"] if row["offered"] else 0.0
        lines.append(f"| {tenant} | {row['offered']} | {row['answered']} | "
                     f"{row['shed']} | {rate:.1%} |")
    return "\n".join(lines)


def load_trace(path) -> list:
    """Events from a Chrome-trace-event file (object format or bare array)."""
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else doc
    return [e for e in events if isinstance(e, dict)]


def trace_spans(events) -> list:
    """Complete ("X") spans with per-span *self* time — duration minus the
    time covered by nested spans on the same (pid, tid) track, recovered
    from interval containment (the Chrome format keeps no explicit tree)."""
    spans = [dict(e) for e in events if e.get("ph") == "X"]
    by_track: dict = defaultdict(list)
    for s in spans:
        s["child_us"] = 0.0
        by_track[(s.get("pid"), s.get("tid"))].append(s)
    for track in by_track.values():
        track.sort(key=lambda s: (s["ts"], -float(s.get("dur", 0.0))))
        stack: list = []
        for s in track:
            while stack and (stack[-1]["ts"] + float(stack[-1].get("dur", 0.0))
                             <= s["ts"] + 1e-9):
                stack.pop()
            if stack:
                stack[-1]["child_us"] += float(s.get("dur", 0.0))
            stack.append(s)
    for s in spans:
        s["self_us"] = max(float(s.get("dur", 0.0)) - s["child_us"], 0.0)
    return spans


def trace_slowest_table(spans, top: int = 15) -> str:
    """Top-N slowest spans by duration."""
    out = ["| span | dur ms | self ms | attrs |", "|---|---|---|---|"]
    ranked = sorted(spans, key=lambda s: -float(s.get("dur", 0.0)))[:top]
    for s in ranked:
        attrs = ", ".join(
            f"{k}={v:.3g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in sorted((s.get("args") or {}).items())[:4])
        out.append(f"| {s.get('name')} | {float(s.get('dur', 0.0))/1e3:.2f} | "
                   f"{s['self_us']/1e3:.2f} | {attrs or '—'} |")
    return "\n".join(out)


def trace_phase_table(spans) -> str:
    """Per-span-name time breakdown (count, total, self, mean)."""
    agg: dict = defaultdict(lambda: [0, 0.0, 0.0])
    for s in spans:
        a = agg[s.get("name")]
        a[0] += 1
        a[1] += float(s.get("dur", 0.0))
        a[2] += s["self_us"]
    total_self = sum(a[2] for a in agg.values()) or 1.0
    out = ["| phase | n | total ms | self ms | mean ms | self % |",
           "|---|---|---|---|---|---|"]
    for name, (n, dur, self_us) in sorted(agg.items(),
                                          key=lambda kv: -kv[1][2]):
        out.append(f"| {name} | {n} | {dur/1e3:.2f} | {self_us/1e3:.2f} | "
                   f"{dur/n/1e3:.2f} | {self_us/total_self:.1%} |")
    return "\n".join(out)


def report_trace(path, top: int = 15):
    events = load_trace(path)
    spans = trace_spans(events)
    if not spans:
        print(f"{path}: no complete spans found")
        return
    n_inst = sum(1 for e in events if e.get("ph") == "i")
    print(f"## Trace {path}: {len(spans)} spans, {n_inst} events\n")
    print(f"### Top {min(top, len(spans))} slowest spans\n")
    print(trace_slowest_table(spans, top))
    print()
    print("### Per-phase time breakdown\n")
    print(trace_phase_table(spans))


def report_decisions(path):
    rows = load_decisions(path)
    print(f"## Cost-model decisions ({path})\n")
    if not rows:
        print("no decision rows found — pass a decisions jsonl or a "
              "--json-out file from mine/stream/serve_rules")
        return
    print(decision_summary(rows))
    print()
    print(decision_table(rows))
    try:
        doc = json.loads(open(path).read())
    except ValueError:
        doc = None
    if isinstance(doc, dict) and doc.get("outcomes"):
        print()
        print("## Admission outcomes\n")
        print(outcome_table(doc["outcomes"]))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default="results/dryrun.jsonl")
    ap.add_argument("--decisions", metavar="JSONL", default=None,
                    help="render the cost-model decision telemetry table from "
                         "a jsonl of CostController.decision_rows dicts or a "
                         "mine/stream/serve_rules --json-out file")
    ap.add_argument("--trace", metavar="JSON", default=None,
                    help="render top-slowest-spans + per-phase breakdown "
                         "from a --trace-out Chrome-trace file")
    ap.add_argument("--top", type=int, default=15,
                    help="rows in the --trace slowest-spans table")
    args = ap.parse_args()
    if args.trace:
        report_trace(args.trace, top=args.top)
        return
    if args.decisions:
        report_decisions(args.decisions)
        return
    cells = load(args.path)
    n_ok = sum(1 for r in cells.values() if r.get("ok"))
    n_skip = sum(1 for r in cells.values() if r.get("skipped"))
    n_fail = len(cells) - n_ok - n_skip
    print(f"## Dry-run status: {n_ok} ok / {n_skip} skipped / {n_fail} failed "
          f"({len(cells)} cells)\n")
    print(dryrun_table(cells))
    print()
    print("## Roofline (single-pod 16×16)\n")
    print(roofline_table(cells))
    print()
    worst, coll = pick_hillclimb(cells)
    print(f"hillclimb candidates: worst-fraction={worst}, most-collective={coll}")


if __name__ == "__main__":
    main()
