"""CLI: frequent-itemset mining with the paper's algorithms.

  PYTHONPATH=src python -m repro.launch.mine --dataset mushroom --min-sup 0.3 \
      --algorithm optimized_vfpc [--input file.txt] [--checkpoint-dir ckpt/]
"""

from __future__ import annotations

import argparse
import json

from repro.core import ALGORITHMS, mine
from repro.core.mapreduce import IMPLS
from repro.data import dataset_by_name, load_transactions
from repro.launch.cliopts import (add_mesh_args, add_obs_args,
                                  add_policy_args, policy_kwargs_from_args,
                                  runtime_from_args, tracer_from_args,
                                  write_obs_outputs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mushroom",
                    help="named synthetic dataset (c20d10k/chess/mushroom/...)")
    ap.add_argument("--input", default=None, help="FIMI-format transaction file")
    ap.add_argument("--min-sup", type=float, default=0.3)
    ap.add_argument("--algorithm", default="optimized_vfpc",
                    choices=sorted(ALGORITHMS))
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--impl", default="auto", choices=("auto", *IMPLS),
                    help="counting impl (auto: pallas on TPU, vertical "
                         "elsewhere)")
    ap.add_argument("--json-out", default=None)
    add_policy_args(ap)
    add_mesh_args(ap)
    add_obs_args(ap)
    args = ap.parse_args()
    tracer = tracer_from_args(args)

    if args.input:
        txns, n_items = load_transactions(args.input)
    else:
        txns, n_items = dataset_by_name(args.dataset, seed=args.seed,
                                        scale=args.scale)
    runtime, mesh_kwargs = runtime_from_args(
        args, impl=None if args.impl == "auto" else args.impl)
    res = mine(txns, n_items=n_items, min_sup=args.min_sup,
               algorithm=args.algorithm, runtime=runtime,
               policy_kwargs=policy_kwargs_from_args(args, args.algorithm),
               checkpoint_dir=args.checkpoint_dir, **mesh_kwargs)

    print(f"algorithm={res.algorithm} min_sup={res.min_sup} "
          f"n_txns={res.n_txns} n_items={res.n_items}")
    print(f"mesh={runtime.mesh_split[0]}x{runtime.mesh_split[1]} "
          f"(data x cand) impl={runtime.impl} "
          f"repartitions={res.repartitions} retries={res.retries}")
    print(f"phases={res.n_phases} dispatches={res.dispatches} "
          f"compiles={res.compiles} total={res.total_seconds:.2f}s")
    for ph in res.phases:
        ks = f"k={ph.k_start}..{ph.k_start + ph.npass - 1}"
        print(f"  phase {ks:10s} width={ph.npass} cands={ph.candidate_counts} "
              f"freq={ph.frequent_counts} {ph.elapsed_seconds:.3f}s "
              f"(gen {ph.gen_seconds:.3f} count {ph.count_seconds:.3f})")
    sizes = {k: int(v[0].shape[0]) for k, v in sorted(res.levels.items())}
    print("frequent itemsets per level:", sizes)
    if res.decisions:
        print(f"cost-model decisions: {len(res.decisions)} "
              f"(render with `python -m repro.launch.report --decisions`)")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"levels": sizes, "phases": res.n_phases,
                       "total_seconds": res.total_seconds,
                       "dispatches": res.dispatches,
                       "decisions": res.decisions}, f, indent=2)
    write_obs_outputs(args, tracer)


if __name__ == "__main__":
    main()
