"""CLI: continuous mine → rules → serve over a streaming transaction window.

  PYTHONPATH=src python -m repro.launch.stream --dataset mushroom \
      --scale 0.12 --min-sup 0.4 --capacity 512 --batch 16 --updates 32

Feeds the dataset through a sliding (or landmark) window in micro-batches
(DESIGN.md §8): each update runs the O(delta) signed counting path — falling
back to policy-driven full re-mining on structural drift or staleness — and
atomically swaps a fresh RuleSet into the live serving engine whenever the
frequent itemsets change.  Optionally replays recommendation queries against
the live engine after every update and reports the path mix, update
throughput and rule-refresh latency percentiles.
"""

from __future__ import annotations

import argparse
import collections
import json
import time

import numpy as np

from repro.core.policy import ALGORITHMS
from repro.data import dataset_by_name, load_transactions
from repro.launch.cliopts import (add_obs_args, add_policy_args,
                                  policy_kwargs_from_args, tracer_from_args,
                                  write_obs_outputs)
from repro.launch.serve_rules import make_queries
from repro.serving.common import latency_percentiles
from repro.stream import StreamMiner
from repro.stream.miner import STREAM_IMPLS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mushroom",
                    help="named synthetic dataset (c20d10k/chess/mushroom/...)")
    ap.add_argument("--input", default=None, help="FIMI-format transaction file")
    ap.add_argument("--scale", type=float, default=0.12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-sup", type=float, default=0.4)
    ap.add_argument("--min-conf", type=float, default=0.7)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--mode", default="sliding", choices=("sliding", "landmark"))
    ap.add_argument("--batch", type=int, default=16,
                    help="transactions per streaming micro-batch")
    ap.add_argument("--updates", type=int, default=32,
                    help="steady-state micro-batch updates to stream")
    ap.add_argument("--algorithm", default="optimized_etdpc",
                    choices=sorted(ALGORITHMS), help="full re-mine driver")
    ap.add_argument("--impl", default="auto", choices=STREAM_IMPLS,
                    help="delta-counting impl (default auto)")
    ap.add_argument("--staleness-factor", type=float, default=1.0)
    ap.add_argument("--track-margin", type=float, default=0.1)
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--queries-per-update", type=int, default=8,
                    help="live recommendation queries after each update (0=off)")
    ap.add_argument("--json-out", default=None)
    add_policy_args(ap)
    add_obs_args(ap)
    args = ap.parse_args()
    tracer = tracer_from_args(args)

    if args.input:
        txns, n_items = load_transactions(args.input)
    else:
        txns, n_items = dataset_by_name(args.dataset, seed=args.seed,
                                        scale=args.scale)
    if not txns:
        print("empty dataset; nothing to stream")
        return

    miner = StreamMiner(
        n_items, args.min_sup, capacity=args.capacity, mode=args.mode,
        algorithm=args.algorithm, min_confidence=args.min_conf,
        impl=args.impl, staleness_factor=args.staleness_factor,
        track_margin=args.track_margin,
        policy_kwargs=policy_kwargs_from_args(args, args.algorithm),
        serve_kwargs={"top_k": args.top_k})

    # prefill: bring the window to capacity (one re-mine builds the tables)
    fill = min(len(txns), args.capacity)
    t0 = time.perf_counter()
    rec = miner.push(txns[:fill])
    print(f"prefill: {fill} txns → {rec.n_frequent} frequent itemsets, "
          f"{rec.n_rules} rules ({rec.path}, {rec.update_seconds:.2f}s)")

    queries = (make_queries(txns, args.queries_per_update * args.updates,
                            seed=args.seed + 1)
               if args.queries_per_update else [])
    paths: collections.Counter = collections.Counter()
    served = 0
    t_stream = time.perf_counter()
    for u in range(args.updates):
        lo = (fill + u * args.batch) % max(len(txns) - args.batch, 1)
        rec = miner.push(txns[lo:lo + args.batch])
        paths[rec.path] += 1
        if args.queries_per_update:
            q = queries[u * args.queries_per_update:
                        (u + 1) * args.queries_per_update]
            served += len(miner.query(q))
    stream_s = time.perf_counter() - t_stream

    ups = [r for r in miner.updates[1:]]
    refresh = [r.refresh_seconds * 1e3 for r in ups if r.levels_changed]
    upd_ms = np.array([r.update_seconds * 1e3 for r in ups])
    print(f"streamed {args.updates} updates × {args.batch} txns in "
          f"{stream_s:.2f}s = {args.updates / stream_s:.1f} updates/s "
          f"({args.updates * args.batch / stream_s:,.0f} txns/s)")
    print(f"paths: {dict(paths)}  re-mines: {miner.n_remines - 1} "
          f"(tracked candidates: {miner.n_tracked})")
    if ups:
        print(f"update latency p50={np.percentile(upd_ms, 50):.1f} ms "
              f"p99={np.percentile(upd_ms, 99):.1f} ms; "
              f"rule refreshes: {len(refresh)} "
              + (f"(p50={np.percentile(refresh, 50):.1f} ms "
                 f"p99={np.percentile(refresh, 99):.1f} ms)" if refresh else ""))
    if args.queries_per_update:
        lat = latency_percentiles(miner.engine.records)
        print(f"served {served} live queries against {miner.engine.n_rules} "
              f"rules (last dispatch p50={lat['p50_ms']:.2f} ms)")
        sample = miner.query([queries[0]])[0]
        for r in sample[:3]:
            print(f"  recommend {r.consequent} "
                  f"(conf={r.confidence:.3f} lift={r.lift:.2f})")
    if args.json_out:
        payload = {
            "updates_per_s": args.updates / stream_s,
            "paths": dict(paths), "n_remines": miner.n_remines,
            "n_frequent": miner.n_frequent, "n_rules": miner.engine.n_rules,
            "update_p50_ms": float(np.percentile(upd_ms, 50)) if ups else 0.0,
            "update_p99_ms": float(np.percentile(upd_ms, 99)) if ups else 0.0,
            # controller telemetry, in the same shape mine/serve_rules emit —
            # `report --decisions` accepts this file directly
            "decisions": miner.controller.decision_rows(),
        }
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)
    write_obs_outputs(args, tracer)


if __name__ == "__main__":
    main()
