"""Production mesh builders.

NOTE: importing this module never touches jax device state; meshes are built
only when the functions are called (after the launcher has set XLA_FLAGS).
"""

from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh.

    Axes: (data, model) single-pod; (pod, data, model) multi-pod — the pod
    axis folds into data parallelism (see repro.sharding.physical_axis).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(axis: str = "data"):
    """1-D mesh over all local devices (tests / CPU benches / mining)."""
    return make_mesh((len(jax.devices()),), (axis,))
