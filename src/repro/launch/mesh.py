"""Production mesh builders.

NOTE: importing this module never touches jax device state; meshes are built
only when the functions are called (after the launcher has set XLA_FLAGS).
"""

from __future__ import annotations

import os

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh.

    Axes: (data, model) single-pod; (pod, data, model) multi-pod — the pod
    axis folds into data parallelism (see repro.sharding.physical_axis).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(axis: str = "data"):
    """1-D mesh over all local devices (tests / CPU benches / mining)."""
    return make_mesh((len(jax.devices()),), (axis,))


def init_distributed(coordinator: str | None = None,
                     num_processes: int | None = None,
                     process_id: int | None = None) -> bool:
    """Initialize ``jax.distributed`` for multi-host mining (DESIGN.md §11).

    Configuration comes from the arguments or, when unset, the standard
    environment variables ``JAX_COORDINATOR_ADDRESS`` / ``JAX_NUM_PROCESSES``
    / ``JAX_PROCESS_ID`` — every worker runs the *same* command line and the
    launcher (SLURM, mpirun, a shell loop) differentiates them by env.  With
    neither set this is a no-op and mining stays single-process (the local
    fallback), so all CLIs can call it unconditionally.

    Must run before any other jax call on each worker; afterwards
    ``jax.devices()`` spans the whole cluster and the mining mesh builders
    below lay shards across hosts transparently.  Returns True when
    multi-process mode was actually initialized.
    """
    coordinator = coordinator or os.environ.get("JAX_COORDINATOR_ADDRESS")
    if num_processes is None:
        num_processes = int(os.environ.get("JAX_NUM_PROCESSES", "0") or 0)
    if process_id is None:
        process_id = int(os.environ.get("JAX_PROCESS_ID", "-1") or -1)
    if not coordinator or num_processes <= 1:
        return False
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=max(process_id, 0))
    return True


def make_mining_mesh(n_data: int | None = None, n_cand: int = 1):
    """2-D ``(data, cand)`` mining mesh over all devices (DESIGN.md §11).

    ``n_data`` defaults to ``n_devices // n_cand``; the product must equal
    the total device count (every device gets a (transaction-shard,
    candidate-shard) cell).  ``n_cand == 1`` still builds the 2-D mesh — the
    runtime treats a size-1 cand axis as candidate replication, and the
    elastic repartitioner can widen it later without a mesh-name change.
    """
    n_dev = len(jax.devices())
    if n_cand < 1:
        raise ValueError(f"n_cand must be >= 1, got {n_cand}")
    if n_data is None:
        if n_dev % n_cand:
            raise ValueError(f"{n_cand} candidate shards do not divide "
                             f"{n_dev} devices")
        n_data = n_dev // n_cand
    if n_data * n_cand != n_dev:
        raise ValueError(f"mesh split {n_data}x{n_cand} != {n_dev} devices")
    return make_mesh((n_data, n_cand), ("data", "cand"))
