"""CLI: mine → rules → serve association-rule recommendation queries.

  PYTHONPATH=src python -m repro.launch.serve_rules --dataset mushroom \
      --scale 0.08 --min-sup 0.35 --min-conf 0.7 --queries 256 --batch 32

Mines the dataset, generates the RuleSet (DESIGN.md §7), then replays a
synthetic query stream (sampled transactions with one item dropped) through
the RuleServeEngine with policy-fused micro-batching, reporting rules/s,
queries/s and per-dispatch latency percentiles.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import generate_ruleset, mine
from repro.core.mapreduce import MapReduceRuntime
from repro.core.policy import ALGORITHMS
from repro.data import dataset_by_name, load_transactions
from repro.launch.cliopts import add_policy_args, policy_kwargs_from_args
from repro.serving import RULE_IMPLS, RuleServeEngine
from repro.serving.common import latency_ms


def make_queries(txns, n_queries: int, seed: int = 0):
    """Sample transactions and drop one random item each — baskets with a
    natural 'missing' consequent for the rules to fill in."""
    rng = np.random.default_rng(seed)
    picks = rng.integers(0, len(txns), n_queries)
    out = []
    for p in picks:
        t = list(txns[p])
        if len(t) > 1:
            t.pop(rng.integers(0, len(t)))
        out.append(t)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mushroom",
                    help="named synthetic dataset (c20d10k/chess/mushroom/...)")
    ap.add_argument("--input", default=None, help="FIMI-format transaction file")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-sup", type=float, default=0.35)
    ap.add_argument("--min-conf", type=float, default=0.7)
    ap.add_argument("--mine-algorithm", default="optimized_vfpc",
                    choices=sorted(ALGORITHMS))
    ap.add_argument("--algorithm", default="optimized_vfpc",
                    choices=sorted(ALGORITHMS),
                    help="query micro-batch fusion policy (spc = per-batch)")
    ap.add_argument("--impl", default="auto", choices=RULE_IMPLS,
                    help="containment-scoring impl (default auto)")
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--max-fuse", type=int, default=16)
    ap.add_argument("--json-out", default=None)
    add_policy_args(ap)
    args = ap.parse_args()

    if args.input:
        txns, n_items = load_transactions(args.input)
    else:
        txns, n_items = dataset_by_name(args.dataset, seed=args.seed,
                                        scale=args.scale)

    res = mine(txns, n_items=n_items, min_sup=args.min_sup,
               algorithm=args.mine_algorithm, runtime=MapReduceRuntime())
    t0 = time.perf_counter()
    rules = generate_ruleset(res, min_confidence=args.min_conf)
    gen_s = time.perf_counter() - t0
    print(f"mined {sum(v[0].shape[0] for v in res.levels.values())} frequent "
          f"itemsets in {res.n_phases} phases "
          f"({res.total_seconds:.2f}s, {res.dispatches} jobs)")
    print(f"rules: {len(rules)} (min_conf={args.min_conf}) in {gen_s*1e3:.1f} ms "
          f"= {len(rules)/max(gen_s, 1e-9):,.0f} rules/s")
    if len(rules) == 0:
        print("no rules above min_conf; lower --min-conf or --min-sup")
        return

    queries = make_queries(txns, args.queries, seed=args.seed + 1)
    batches = [queries[i:i + args.batch]
               for i in range(0, len(queries), args.batch)]
    if not batches:
        print("nothing to serve; raise --queries")
        return
    eng = RuleServeEngine(rules, top_k=args.top_k, impl=args.impl,
                          algorithm=args.algorithm, max_fuse=args.max_fuse,
                          policy_kwargs=policy_kwargs_from_args(
                              args, args.algorithm),
                          latency_budget_ms=args.latency_budget_ms)
    eng.warmup(args.batch * args.max_fuse)      # compile buckets + autotune
    t0 = time.perf_counter()
    results, records = eng.serve(batches)
    total_s = time.perf_counter() - t0

    lat_ms = latency_ms(records)
    fused = sum(1 for r in records if r.n_batches > 1)
    print(f"served {len(queries)} queries in {len(records)} dispatches "
          f"({fused} fused) with algorithm={args.algorithm} impl={args.impl}")
    print(f"throughput: {len(queries)/total_s:,.0f} queries/s   "
          f"latency p50={np.percentile(lat_ms, 50):.2f} ms "
          f"p99={np.percentile(lat_ms, 99):.2f} ms")
    sample = results[0][0]
    print(f"sample query {queries[0][:8]}{'...' if len(queries[0]) > 8 else ''} →")
    for rec in sample:
        print(f"  recommend {rec.consequent} "
              f"(conf={rec.confidence:.3f} lift={rec.lift:.2f})")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"n_rules": len(rules), "rules_per_s":
                       len(rules) / max(gen_s, 1e-9),
                       "queries_per_s": len(queries) / total_s,
                       "p50_ms": float(np.percentile(lat_ms, 50)),
                       "p99_ms": float(np.percentile(lat_ms, 99)),
                       "dispatches": len(records), "fused": fused}, f, indent=2)


if __name__ == "__main__":
    main()
