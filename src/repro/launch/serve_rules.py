"""CLI: mine → rules → serve association-rule recommendation queries.

  PYTHONPATH=src python -m repro.launch.serve_rules --dataset mushroom \
      --scale 0.08 --min-sup 0.35 --min-conf 0.7 --queries 256 --batch 32

Mines the dataset, generates the RuleSet (DESIGN.md §7), then replays a
synthetic query stream (sampled transactions with one item dropped) through
the RuleServeEngine with policy-fused micro-batching, reporting rules/s,
queries/s and per-dispatch latency percentiles.

Multi-tenant / SLO serving (DESIGN.md §12): ``--tenants N`` round-robin
splits the transaction stream, mines one RuleSet per tenant and serves the
mixed-tenant query stream through one packed arena; ``--rate-qps`` switches
to an open-loop arrival clock with ``--latency-slo-ms`` admission and an LRU
result cache, reporting sustained qps, p99 and shed rate.  ``--json-out``
records per-query shed/cache/fused outcomes plus the controller's decision
telemetry, which ``launch/report.py --decisions`` renders.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import generate_ruleset, mine
from repro.core.mapreduce import MapReduceRuntime
from repro.core.policy import ALGORITHMS
from repro.costmodel import CostController
from repro.data import dataset_by_name, load_transactions
from repro.launch.cliopts import (add_obs_args, add_policy_args,
                                  add_serving_args, policy_kwargs_from_args,
                                  tracer_from_args, write_obs_outputs)
from repro.serving import (RULE_IMPLS, OpenLoopServer, RuleServeEngine,
                           RuleStore)
from repro.serving.common import latency_ms


def make_queries(txns, n_queries: int, seed: int = 0):
    """Sample transactions and drop one random item each — baskets with a
    natural 'missing' consequent for the rules to fill in."""
    rng = np.random.default_rng(seed)
    picks = rng.integers(0, len(txns), n_queries)
    out = []
    for p in picks:
        t = list(txns[p])
        if len(t) > 1:
            t.pop(rng.integers(0, len(t)))
        out.append(t)
    return out


def mine_tenants(txns, n_items: int, n_tenants: int, args):
    """Round-robin split the stream and mine one RuleSet per tenant slice —
    N genuinely different catalogs from one dataset, no extra data."""
    tenants: dict = {}
    slices: dict = {}
    for i in range(n_tenants):
        name = f"t{i}"
        slice_ = txns[i::n_tenants]
        res = mine(slice_, n_items=n_items, min_sup=args.min_sup,
                   algorithm=args.mine_algorithm, runtime=MapReduceRuntime())
        tenants[name] = generate_ruleset(res, min_confidence=args.min_conf)
        slices[name] = slice_
    return tenants, slices


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mushroom",
                    help="named synthetic dataset (c20d10k/chess/mushroom/...)")
    ap.add_argument("--input", default=None, help="FIMI-format transaction file")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-sup", type=float, default=0.35)
    ap.add_argument("--min-conf", type=float, default=0.7)
    ap.add_argument("--mine-algorithm", default="optimized_vfpc",
                    choices=sorted(ALGORITHMS))
    ap.add_argument("--algorithm", default="optimized_vfpc",
                    choices=sorted(ALGORITHMS),
                    help="query micro-batch fusion policy (spc = per-batch)")
    ap.add_argument("--impl", default="auto", choices=RULE_IMPLS,
                    help="containment-scoring impl (default auto)")
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--max-fuse", type=int, default=16)
    ap.add_argument("--json-out", default=None)
    add_policy_args(ap)
    add_serving_args(ap)
    add_obs_args(ap)
    args = ap.parse_args()
    tracer = tracer_from_args(args)

    if args.input:
        txns, n_items = load_transactions(args.input)
    else:
        txns, n_items = dataset_by_name(args.dataset, seed=args.seed,
                                        scale=args.scale)

    controller = CostController()
    record: dict = {}
    t0 = time.perf_counter()
    if args.tenants > 1:
        tenants, slices = mine_tenants(txns, n_items, args.tenants, args)
        gen_s = time.perf_counter() - t0
        n_rules = sum(len(r) for r in tenants.values())
        per = ", ".join(f"{t}:{len(r)}" for t, r in tenants.items())
        print(f"mined {args.tenants} tenant slices in {gen_s:.2f}s — "
              f"{n_rules} rules ({per}, min_conf={args.min_conf})")
        if n_rules == 0:
            print("no rules above min_conf; lower --min-conf or --min-sup")
            return
        store = RuleStore(tenants=tenants)
        names = list(tenants)
        queries = []
        for i in range(args.queries):
            name = names[i % len(names)]
            q = make_queries(slices[name], 1, seed=args.seed + 1 + i)[0]
            queries.append((name, q))
        record["tenants"] = {t: len(r) for t, r in tenants.items()}
    else:
        res = mine(txns, n_items=n_items, min_sup=args.min_sup,
                   algorithm=args.mine_algorithm, runtime=MapReduceRuntime())
        t1 = time.perf_counter()
        rules = generate_ruleset(res, min_confidence=args.min_conf)
        gen_s = time.perf_counter() - t1
        print(f"mined {sum(v[0].shape[0] for v in res.levels.values())} "
              f"frequent itemsets in {res.n_phases} phases "
              f"({res.total_seconds:.2f}s, {res.dispatches} jobs)")
        print(f"rules: {len(rules)} (min_conf={args.min_conf}) in "
              f"{gen_s*1e3:.1f} ms = "
              f"{len(rules)/max(gen_s, 1e-9):,.0f} rules/s")
        if len(rules) == 0:
            print("no rules above min_conf; lower --min-conf or --min-sup")
            return
        store = RuleStore(rules)
        queries = make_queries(txns, args.queries, seed=args.seed + 1)
        record["rules_per_s"] = len(rules) / max(gen_s, 1e-9)
        n_rules = len(rules)
    record["n_rules"] = n_rules

    eng = RuleServeEngine(store, top_k=args.top_k, impl=args.impl,
                          algorithm=args.algorithm, max_fuse=args.max_fuse,
                          policy_kwargs=policy_kwargs_from_args(
                              args, args.algorithm),
                          latency_budget_ms=args.latency_budget_ms,
                          controller=controller)
    eng.warmup(args.batch * args.max_fuse)      # compile buckets + autotune

    if args.rate_qps:
        serve_open_loop(eng, queries, args, controller, record)
    else:
        serve_closed_loop(eng, queries, args, record)
    record["decisions"] = controller.decision_rows()

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(record, f, indent=2)
    write_obs_outputs(args, tracer)


def serve_closed_loop(eng, queries, args, record: dict) -> None:
    """Back-to-back batch replay: the best-case throughput number."""
    batches = [queries[i:i + args.batch]
               for i in range(0, len(queries), args.batch)]
    t0 = time.perf_counter()
    results, records = eng.serve(batches)
    total_s = time.perf_counter() - t0

    lat_ms = latency_ms(records)
    fused = sum(1 for r in records if r.n_batches > 1)
    print(f"served {len(queries)} queries in {len(records)} dispatches "
          f"({fused} fused) with algorithm={args.algorithm} impl={args.impl}")
    print(f"throughput: {len(queries)/total_s:,.0f} queries/s   "
          f"latency p50={np.percentile(lat_ms, 50):.2f} ms "
          f"p99={np.percentile(lat_ms, 99):.2f} ms")
    q0 = queries[0][1] if isinstance(queries[0], tuple) else queries[0]
    sample = results[0][0]
    print(f"sample query {q0[:8]}{'...' if len(q0) > 8 else ''} →")
    for rec in sample:
        print(f"  recommend {rec.consequent} "
              f"(conf={rec.confidence:.3f} lift={rec.lift:.2f})")
    record.update({
        "queries_per_s": len(queries) / total_s,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "dispatches": len(records), "fused": fused})


def serve_open_loop(eng, queries, args, controller, record: dict) -> None:
    """Open-loop arrival replay (DESIGN.md §12): virtual arrival clock at
    ``--rate-qps``, real measured dispatch costs, SLO admission + caching."""
    from repro.obs.metrics import get_registry
    srv = OpenLoopServer(
        eng, latency_slo_ms=args.latency_slo_ms, batch=args.batch,
        max_wait_ms=args.max_wait_ms, cache_size=args.cache_size,
        fair_shedding=not args.no_fair_shedding, controller=controller,
        registry=get_registry())   # one server: feed the process snapshot
    rng = np.random.default_rng(args.seed + 2)
    gaps = rng.uniform(0.7, 1.3, len(queries)) / args.rate_qps
    t = 0.0
    for q, gap in zip(queries, gaps):
        t += gap
        if isinstance(q, tuple):
            srv.submit(q[1], t, tenant=q[0])
        else:
            srv.submit(q, t)
    srv.flush()

    s = srv.summary()
    answered = s["served"] + s["cached"]
    makespan = max(srv.busy_until, t)
    slo = ("" if args.latency_slo_ms is None
           else f" vs {args.latency_slo_ms:.1f} ms SLO")
    print(f"open loop @ {args.rate_qps:,.0f} qps offered: "
          f"{answered}/{s['n_queries']} answered "
          f"({s['cached']} cached, {s['shed']} shed = "
          f"{s['shed_rate']:.1%}) in {s['dispatches']} dispatches")
    print(f"sustained: {answered/max(makespan, 1e-9):,.0f} qps   "
          f"latency p50={s['p50_ms']:.2f} ms p99={s['p99_ms']:.2f} ms{slo}")
    record["open_loop"] = {
        "rate_qps": args.rate_qps,
        "latency_slo_ms": args.latency_slo_ms,
        "sustained_qps": answered / max(makespan, 1e-9), **s}
    record["outcomes"] = s
    record["per_query"] = [o.as_dict() for o in srv.outcomes]


if __name__ == "__main__":
    main()
