"""CLI: serve a model with paper-policy multi-step decode fusion.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --batch 8 --prompt-len 16 --max-new 64 --algorithm optimized_vfpc
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core.policy import ALGORITHMS
from repro.launch.cliopts import add_policy_args, policy_kwargs_from_args
from repro.models import build_model
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--algorithm", default="optimized_vfpc",
                    choices=sorted(ALGORITHMS))
    ap.add_argument("--eos-id", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    add_policy_args(ap)
    args = ap.parse_args()

    model = build_model(args.arch, smoke=args.smoke)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(1, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)

    eng = ServeEngine(model, params,
                      cache_len=args.prompt_len + args.max_new + 8,
                      algorithm=args.algorithm,
                      policy_kwargs=policy_kwargs_from_args(
                          args, args.algorithm),
                      latency_budget_ms=args.latency_budget_ms)
    toks, records = eng.generate(prompts, max_new_tokens=args.max_new,
                                 eos_id=args.eos_id)
    total_t = sum(r.elapsed for r in records)
    total_tok = sum(r.tokens_emitted for r in records)
    print(f"algorithm={args.algorithm} dispatches={len(records)} "
          f"tokens={total_tok} wasted={sum(r.wasted_tokens for r in records)} "
          f"decode_time={total_t:.3f}s ({total_tok/max(total_t,1e-9):.1f} tok/s)")
    for r in records:
        print(f"  phase {r.phase_idx:3d} npass={r.npass:2d} "
              f"active={r.active_before} {r.elapsed*1e3:.1f} ms")
    print("first row tokens:", toks[0][:24].tolist())


if __name__ == "__main__":
    main()
