"""Model/arch configuration system.

Every assigned architecture gets a module in this package exposing ``CONFIG``
(the exact published dims) and ``SMOKE_CONFIG`` (a reduced same-family config
for CPU smoke tests).  ``get_config(name)`` resolves either.
"""

from __future__ import annotations

import dataclasses
import importlib

VOCAB_PAD_MULTIPLE = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 → d_model // n_heads
    qk_norm: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1            # MoE FFN on layers where (idx % moe_every) == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0           # 0: all layers attention; n>0: attention iff idx % n == attn_offset; -1: no attention (pure SSM)
    attn_offset: int = 3
    # encoder-decoder
    n_encoder_layers: int = 0
    enc_seq: int = 1500
    # modality frontend stubs
    frontend: str = "none"        # none | vision_stub | audio_stub
    n_frontend_tokens: int = 0
    # attention partitioning/chunking
    q_head_pad_group: int = 0     # pad GQA group size to this (0 = no padding);
                                  # makes padded q-heads divisible by the model
                                  # axis when the real count is not (DESIGN.md)
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    # misc
    use_rope: bool = True          # False → learned absolute positions (whisper)
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"    # full | dots (save matmul outputs in bwd)
    # training
    max_seq_len: int = 8192

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def group_size(self) -> int:
        """Real GQA group size (q heads per kv head)."""
        return self.n_heads // self.n_kv_heads

    @property
    def padded_group_size(self) -> int:
        return max(self.q_head_pad_group, self.group_size)

    @property
    def padded_heads(self) -> int:
        """Q heads incl. group padding (layout: (kv_head, group) flattened)."""
        return self.n_kv_heads * self.padded_group_size

    @property
    def vocab_padded(self) -> int:
        m = VOCAB_PAD_MULTIPLE
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_encoder_decoder(self) -> bool:
        return self.family == "encdec"

    @property
    def experts_padded(self) -> int:
        """Experts padded to a multiple of 16 for clean EP on the model axis."""
        if self.n_experts == 0:
            return 0
        return ((self.n_experts + 15) // 16) * 16

    def layer_kind(self, idx: int) -> str:
        """"attn" or "ssm" mixer for decoder layer ``idx``."""
        if self.attn_every == -1:
            return "ssm"
        if self.attn_every == 0:
            return "attn"
        return "attn" if idx % self.attn_every == self.attn_offset else "ssm"

    def ffn_kind(self, idx: int) -> str:
        """"moe", "dense", or "none" FFN for decoder layer ``idx``."""
        if self.n_experts and idx % self.moe_every == self.moe_offset:
            return "moe"
        return "dense" if self.d_ff > 0 else "none"

    def param_count(self) -> int:
        """Total parameters (approximate analytic count; embeddings included)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        attn = d * hd * (n_q + 2 * n_kv) + n_q * hd * d
        dense_ffn = 3 * d * self.d_ff
        moe_ffn = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
        d_inner = self.ssm_expand * d
        n_ssm_heads = d_inner // self.ssm_head_dim
        ssm = (d * (2 * d_inner + 2 * self.ssm_state + n_ssm_heads)
               + d_inner * self.ssm_conv + d_inner * d + 2 * n_ssm_heads)
        total = self.vocab_padded * d  # embeddings
        if not self.tie_embeddings:
            total += self.vocab_padded * d
        layers = self.n_layers + self.n_encoder_layers
        for i in range(self.n_layers):
            total += attn if self.layer_kind(i) == "attn" else ssm
            total += moe_ffn if self.ffn_kind(i) == "moe" else dense_ffn
            total += 2 * d
        for _ in range(self.n_encoder_layers):  # encoder: attn + dense ffn (+cross in decoder, approx)
            total += attn + dense_ffn + 2 * d
        if self.is_encoder_decoder:  # cross attention in decoder layers
            total += self.n_layers * (attn + 2 * d)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts instead of all)."""
        if not self.n_experts:
            return self.param_count()
        full_moe = self.n_experts * 3 * self.d_model * self.moe_d_ff
        act_moe = self.top_k * 3 * self.d_model * self.moe_d_ff
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.ffn_kind(i) == "moe")
        return int(self.param_count() - n_moe_layers * (full_moe - act_moe))


ARCH_NAMES = [
    "internvl2_76b", "smollm_135m", "qwen3_14b", "starcoder2_15b",
    "codeqwen15_7b", "granite_moe_3b", "qwen3_moe_30b", "whisper_small",
    "jamba_v01_52b", "mamba2_370m",
]

# external id (assignment spelling) -> module name
ARCH_IDS = {
    "internvl2-76b": "internvl2_76b",
    "smollm-135m": "smollm_135m",
    "qwen3-14b": "qwen3_14b",
    "starcoder2-15b": "starcoder2_15b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "whisper-small": "whisper_small",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "mamba2-370m": "mamba2_370m",
}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod_name = ARCH_IDS.get(name, name.replace("-", "_").replace(".", ""))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


# -- input shapes assigned to every architecture ------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# pure full-attention archs skip long_500k (assignment rule; DESIGN.md §7)
SUBQUADRATIC_ARCHS = {"jamba-v0.1-52b", "mamba2-370m"}


def cell_is_runnable(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in SUBQUADRATIC_ARCHS:
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention (skip per assignment)"
    return True, ""
