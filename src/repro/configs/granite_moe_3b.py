"""granite-moe-3b-a800m — IBM granite MoE [hf:ibm-granite family].

Assignment dims: 32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert)
vocab=49155, MoE 40 experts top-8, every layer.
40 experts are EP-padded to 48 on the 16-way model axis (3/device).
Vocab 49155 is padded to 49408 (multiple of 256) for clean vocab TP.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    n_experts=40, top_k=8, moe_d_ff=512, moe_every=1,
    rope_theta=1e4,
    # 24 q heads don't divide the model axis: pad GQA groups 3→4 (32 heads).
    q_head_pad_group=4,
)

SMOKE_CONFIG = ModelConfig(
    name="granite-moe-3b-a800m-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=515,
    n_experts=8, top_k=2, moe_d_ff=64, moe_every=1,
)
