"""mamba2-370m — SSD (state-space duality), attention-free [arXiv:2405.21060].

Assignment dims: 48L d_model=1024 (attn-free) d_ff=0 vocab=50280,
ssm_state=128.  Pure Mamba-2 blocks (mixer only, no FFN), expand=2,
head_dim=64 → 32 SSD heads.  Vocab padded 50280 → 50432.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16,  # attn unused
    d_ff=0, vocab_size=50280, tie_embeddings=True,
    attn_every=-1,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-370m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=512, tie_embeddings=True,
    attn_every=-1,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_conv=4,
)
