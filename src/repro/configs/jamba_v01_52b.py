"""jamba-v0.1-52b — Mamba+attention 1:7 interleave, MoE 16e top-2 [arXiv:2403.19887].

Assignment dims: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16 experts top-2.  Layer pattern per the paper: within each 8-layer block,
layer 3 (0-based) is attention, the rest are Mamba; MoE replaces the dense FFN
on every second layer (odd indices).

Adaptation note (DESIGN.md §7): the published Jamba uses Mamba-1 selective-scan
mixers (d_state 16); this framework implements the Mamba-2 SSD mixer and reuses
it here with ssm_state=16 — same asymptotics, TPU-friendlier chunked form.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    n_experts=16, top_k=2, moe_d_ff=14336, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=3,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-v0.1-52b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    n_experts=4, top_k=2, moe_d_ff=128, moe_every=2, moe_offset=1,
    attn_every=4, attn_offset=3,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_conv=4,
)
