"""internvl2-76b — InternViT + InternLM2 backbone [arXiv:2404.16821].

Assignment dims: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
VLM: the ViT frontend is a STUB — ``input_specs`` provides precomputed patch
embeddings (n_frontend_tokens × d_model) which overwrite the first positions
of the token embedding sequence.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    frontend="vision_stub", n_frontend_tokens=256,
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-76b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    frontend="vision_stub", n_frontend_tokens=8,
)
