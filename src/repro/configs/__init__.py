"""Arch configs: one module per assigned architecture + shape definitions."""

from .base import (ARCH_IDS, ARCH_NAMES, SHAPES, SUBQUADRATIC_ARCHS,
                   ModelConfig, ShapeConfig, cell_is_runnable, get_config)

__all__ = [
    "ARCH_IDS", "ARCH_NAMES", "SHAPES", "SUBQUADRATIC_ARCHS",
    "ModelConfig", "ShapeConfig", "cell_is_runnable", "get_config",
]
