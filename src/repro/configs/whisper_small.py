"""whisper-small — encoder-decoder audio backbone [arXiv:2212.04356].

Assignment dims: 12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
Enc-dec: 12 encoder + 12 decoder layers.  The conv/log-mel frontend is a STUB —
``input_specs`` provides precomputed frame embeddings (enc_seq × d_model).
Positions are learned-absolute (no RoPE), as in the published model.
Vocab padded 51865 → 52224 for vocab TP.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_encoder_layers=12,
    d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    frontend="audio_stub", enc_seq=1500, use_rope=False,
    max_seq_len=32768,  # learned decoder positions must cover the 32k shapes
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-small-smoke", family="encdec",
    n_layers=2, n_encoder_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    frontend="audio_stub", enc_seq=32, use_rope=False,
)
