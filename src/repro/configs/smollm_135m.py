"""smollm-135m — llama-arch small [hf:HuggingFaceTB/SmolLM-135M].

Assignment dims: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
Also the ~100M end-to-end training example model (examples/train_lm.py).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, head_dim=64,
    d_ff=1536, vocab_size=49152, tie_embeddings=True,
    rope_theta=1e4,
)

SMOKE_CONFIG = ModelConfig(
    name="smollm-135m-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512, tie_embeddings=True,
)
