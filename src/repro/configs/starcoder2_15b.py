"""starcoder2-15b — GQA, RoPE [arXiv:2402.19173].

Assignment dims: 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
    d_ff=24576, vocab_size=49152,
    rope_theta=1e5,
)

SMOKE_CONFIG = ModelConfig(
    name="starcoder2-15b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512,
)
