"""qwen3-14b — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

Assignment dims: 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
Note: 40 q-heads / 8 kv-heads do not divide the 16-way model axis evenly;
head sharding is GSPMD-padded (roofline impact discussed in EXPERIMENTS.md).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab_size=151936, qk_norm=True,
    rope_theta=1e6,
    # 40 q heads don't divide the 16-way model axis: pad GQA groups 5→6
    # (48 padded heads, masked) so attention TP-shards cleanly.
    q_head_pad_group=6,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-14b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=5, n_kv_heads=1, head_dim=16,
    d_ff=160, vocab_size=512, qk_norm=True,
)
