"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

Assignment dims: 48L d_model=2048 32H (GQA kv=4) d_ff=768 (per expert)
vocab=151936, MoE 128e top-8 every layer.  head_dim=128 per the published
model (q projection 2048 → 4096).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936, qk_norm=True,
    n_experts=128, top_k=8, moe_d_ff=768, moe_every=1,
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=64, vocab_size=512, qk_norm=True,
    n_experts=8, top_k=2, moe_d_ff=64, moe_every=1,
)
