"""Streaming incremental-mining subsystem (DESIGN.md §8).

Turns the repo from "mine once" into "mine continuously": a device-resident
:class:`TransactionWindow` absorbs append/evict micro-batches, tracked
candidate tables are maintained with O(delta) signed counting
(``kernels/delta_count.py``), and a :class:`StreamMiner` republishes exact
frequent itemsets — and a fresh :class:`~repro.core.rules.RuleSet` into its
live :class:`~repro.serving.rules_engine.RuleServeEngine` — after every
update, falling back to policy-driven full re-mining when the itemset
structure drifts.
"""

from .window import TransactionWindow, WindowDelta
from .tables import TrackedTables, derive_frequent, levels_equal
from .miner import StreamMiner, StreamUpdate

__all__ = [
    "TransactionWindow", "WindowDelta",
    "TrackedTables", "derive_frequent", "levels_equal",
    "StreamMiner", "StreamUpdate",
]
