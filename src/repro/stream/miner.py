"""StreamMiner: continuous exact mining over a transaction window
(DESIGN.md §8).

Every window mutation takes one of two paths:

* **delta** — one O(delta) signed counting dispatch updates all tracked
  candidate counts (``kernels/delta_count.py``), and the host cascade
  (:func:`~repro.stream.tables.derive_frequent`) re-derives the frequent
  levels exactly from the running tables;
* **re-mine** — the always-available fallback: a full policy-driven
  ``mine()`` over the window contents (reusing ``core/phases.py`` /
  ``core/policy.py`` pass combining) plus one extra MapReduce job counting
  the negative border, which re-tightens the tracked tables.

Re-mining triggers ETDPC-style: *mandatorily* when the cascade reports
structural drift (a needed candidate is untracked — its count is unknown),
and *opportunistically* when ``drift × staleness`` exceeds the *predicted*
cost of re-mining the current window — ``drift`` being the fraction of the
window churned since the last re-mine and ``staleness`` the delta-counting
seconds accumulated since then.  The prediction comes from the shared
:class:`~repro.costmodel.CostController` (DESIGN.md §9), calibrated from
every completed re-mine: unlike the raw last-measured seconds it replaced,
it scales with the window, so a tiny init-time mine no longer freezes the
estimate far below the true post-growth re-mine cost (the cold-start bug).

Either way the published state is exact: frequent itemsets, supports and the
generated :class:`~repro.core.rules.RuleSet` are byte-identical to a
from-scratch mine of the current window at every step (property-tested in
``tests/test_stream.py``).  When the published levels change, a fresh RuleSet
is atomically swapped into the live
:class:`~repro.serving.rules_engine.RuleServeEngine`
(:meth:`~repro.serving.rules_engine.RuleServeEngine.swap_rules`), so
recommendation queries always run against complete, current rules.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.drivers import MiningResult, mine
from repro.core.mapreduce import MapReduceRuntime
from repro.core.phases import bucket_pad
from repro.core.policy import ALGORITHMS
from repro.core.rules import generate_ruleset
from repro.kernels.delta_count import delta_count
from repro.obs.trace import current_tracer
from repro.serving.rules_engine import RuleServeEngine

from .tables import (TrackedTables, build_tracked_levels, derive_frequent,
                     levels_equal)
from .window import TransactionWindow

STREAM_IMPLS = ("auto", "jnp", "pallas", "pallas_interpret", "matmul",
                "matmul_pallas", "matmul_pallas_interpret")


@dataclasses.dataclass
class StreamUpdate:
    """Per-update trace record (the streaming analogue of PhaseResult)."""
    seq: int
    path: str                 # "delta" | "remine" | "remine_structural" |
                              # "remine_staleness" | "empty"
    n_added: int
    n_evicted: int
    window_size: int
    update_seconds: float     # total wall time of the update
    delta_seconds: float      # signed counting + cascade time (delta path)
    remine_seconds: float     # full re-mine + border job time (re-mine paths)
    refresh_seconds: float    # RuleSet regeneration + atomic engine swap
    n_frequent: int
    n_rules: int
    levels_changed: bool


class StreamMiner:
    """Continuously mine a streaming transaction window, exactly.

    Args:
      n_items: item catalog size.
      min_sup: fractional minimum support over the *current* window size.
      capacity / mode: window sizing (see :class:`TransactionWindow`).
      algorithm: pass-combining driver for full re-mines (core/policy.py).
      min_confidence: rule threshold for the published RuleSet.
      runtime: shared MapReduceRuntime (defaults to all local devices).
      impl: delta-counting implementation — popcount ("jnp"/"pallas") or
        bit-plane matmul ("matmul"/"matmul_pallas") forms (DESIGN.md §10);
        "auto" follows the autotuner's cross-family plan winner (static
        fallback: pallas on TPU, jnp elsewhere); "*pallas" off-TPU degrades
        to interpret mode.
      staleness_factor: β-style scale on the re-mine trigger — re-mine when
        ``drift × staleness > staleness_factor × predicted_remine_seconds``.
      controller: a :class:`repro.costmodel.CostController` shared with the
        embedded ``mine()`` calls; predicts re-mine cost at the current
        window size and records per-decision telemetry.  Default: a
        controller on the process-wide shared model.
      policy_kwargs: hyperparameters for the re-mine driver's policy
        (``time_scale``, β's, ... — forwarded to ``mine()``).
      track_margin: fractional support headroom of the tracked tables
        (see ``tables.build_tracked_levels``): larger margins absorb more
        near-threshold churn on the delta path at the cost of tracking (and
        delta-counting) more border candidates.
      refresh_rules: regenerate + atomically swap the RuleSet into
        ``self.engine`` whenever the published levels change.
      warm_queries: pre-compile the swapped-in engine up to this many queries
        *before* publishing the swap (0 = no pre-warm).
      oracle_check: after every update, run a from-scratch ``mine()`` on the
        window and assert exact equality — the equivalence oracle (slow;
        tests/CI only).
      serve_kwargs: extra RuleServeEngine keyword args.
    """

    def __init__(self, n_items: int, min_sup: float, *,
                 capacity: int = 1024, mode: str = "sliding",
                 algorithm: str = "optimized_etdpc",
                 min_confidence: float = 0.6,
                 runtime: MapReduceRuntime | None = None,
                 impl: str = "auto", staleness_factor: float = 1.0,
                 track_margin: float = 0.1,
                 refresh_rules: bool = True, warm_queries: int = 0,
                 oracle_check: bool = False,
                 serve_kwargs: dict | None = None, autotune: bool = True,
                 controller=None, policy_kwargs: dict | None = None):
        if algorithm not in ALGORITHMS:
            raise ValueError(
                f"unknown algorithm {algorithm!r}; options: {sorted(ALGORITHMS)}")
        if impl not in STREAM_IMPLS:
            raise ValueError(
                f"unknown impl {impl!r}; options: {STREAM_IMPLS}")
        self.n_items = n_items
        self.min_sup = min_sup
        self.algorithm = algorithm
        self.min_confidence = min_confidence
        self.impl = impl
        self.staleness_factor = staleness_factor
        self.track_margin = track_margin
        self.refresh_rules = refresh_rules
        self.warm_queries = warm_queries
        self.oracle_check = oracle_check
        self.autotune = autotune
        self.policy_kwargs = policy_kwargs
        self.window = TransactionWindow(n_items, capacity=capacity, mode=mode)
        self.runtime = runtime or MapReduceRuntime()
        if controller is None:
            from repro.costmodel import CostController
            controller = CostController()
        self.controller = controller
        self._tables: TrackedTables | None = None
        self._published: dict = {}
        self.engine = RuleServeEngine(
            generate_ruleset(self._snapshot({}), min_confidence),
            **(serve_kwargs or {}))
        self.updates: list[StreamUpdate] = []
        self.n_remines = 0
        self._remine_seconds: float | None = None   # last measured full cost
        self._delta_seconds_accum = 0.0             # since the last re-mine
        self._rows_since_remine = 0

    # -- public surface --------------------------------------------------------

    @property
    def levels(self) -> dict:
        """Published frequent levels ``{k: (masks, counts)}`` — exact for the
        current window."""
        return self._published

    @property
    def n_frequent(self) -> int:
        return int(sum(v[0].shape[0] for v in self._published.values()))

    @property
    def n_tracked(self) -> int:
        """Candidates currently carried by the running count tables."""
        return self._tables.n_tracked if self._tables is not None else 0

    def push(self, transactions=None, *, masks=None) -> StreamUpdate:
        """Append a micro-batch (item-id lists or pre-packed masks) and
        refresh the published state."""
        return self._apply(self.window.append(transactions, masks=masks))

    def evict(self, n: int) -> StreamUpdate:
        """Evict the ``n`` oldest transactions and refresh."""
        return self._apply(self.window.evict(n))

    def result(self) -> MiningResult:
        """MiningResult-shaped snapshot of the published exact state."""
        return self._snapshot(dict(self._published))

    def query(self, baskets, top_k: int | None = None):
        """Recommendations from the live (last-swapped) RuleSet."""
        return self.engine.query(baskets, top_k=top_k)

    # -- update machinery ------------------------------------------------------

    def _snapshot(self, levels: dict) -> MiningResult:
        return MiningResult(
            algorithm=f"stream[{self.algorithm}]", min_sup=self.min_sup,
            n_txns=self.window.size, n_items=self.n_items, levels=levels,
            phases=[], total_seconds=0.0,
            dispatches=self.runtime.stats.dispatches,
            compiles=self.runtime.stats.compiles)

    def _predicted_remine_seconds(self) -> float | None:
        """Re-mine cost predicted for the *current* window size — grows with
        the window even when the only observation is the tiny init-time mine
        (the cold-start under-prediction fix, DESIGN.md §9)."""
        predicted = self.controller.predict_remine(self.window.size)
        return predicted if predicted is not None else self._remine_seconds

    def _staleness_triggered(self) -> bool:
        if self.window.size == 0 or self._remine_seconds is None:
            return False
        drift = self._rows_since_remine / self.window.size
        return self.controller.should_remine(
            drift=drift, staleness_seconds=self._delta_seconds_accum,
            window_rows=self.window.size,
            staleness_factor=self.staleness_factor,
            fallback_seconds=self._remine_seconds)

    def _remine(self) -> dict:
        """Full from-scratch mine + per-level border jobs; re-tightens the
        tables around the current window (margin-expanded, see tables.py)."""
        t0 = time.perf_counter()
        remine_span = current_tracer().span("stream.remine",
                                            window=self.window.size)
        contents = self.window.contents()
        res = mine(db_masks=contents, n_items=self.n_items,
                   min_sup=self.min_sup, algorithm=self.algorithm,
                   runtime=self.runtime, controller=self.controller,
                   policy_kwargs=self.policy_kwargs)
        db_sharded = self.runtime.scatter_db(contents, n_items=self.n_items)

        def count_fn(masks):
            return self.runtime.phase_count(
                db_sharded, bucket_pad(masks))[:masks.shape[0]]

        tracked = build_tracked_levels(
            res.levels, self.n_items, self.min_sup * self.window.size,
            self.track_margin, count_fn)
        self._tables = TrackedTables(tracked)
        self._remine_seconds = time.perf_counter() - t0
        remine_span.set(seconds=self._remine_seconds,
                        n_tracked=self._tables.n_tracked).close()
        # calibrate the predictor: one sample per completed re-mine, in the
        # window-rows ops basis (mine + border jobs + table rebuild, end to end)
        self.controller.observe_remine(self.window.size, self._remine_seconds)
        self._delta_seconds_accum = 0.0
        self._rows_since_remine = 0
        self.n_remines += 1
        return dict(res.levels)

    def _apply(self, delta) -> StreamUpdate:
        tracer = current_tracer()
        t0 = time.perf_counter()
        upd_span = tracer.span("stream.update", seq=len(self.updates),
                               n_added=delta.n_added,
                               n_evicted=delta.n_evicted)
        delta_s = remine_s = 0.0
        if self.window.size == 0:
            # empty window: min_count would be 0 and "frequent" degenerate —
            # publish the empty state and force a re-mine on the next fill
            new_levels: dict | None = {}
            self._tables = None
            path = "empty"
        elif self._tables is None:
            new_levels = self._remine()
            remine_s = self._remine_seconds
            path = "remine"
        else:
            td = time.perf_counter()
            with tracer.span("stream.delta_count",
                             n_tracked=self._tables.n_tracked,
                             impl=self.impl):
                deltas = delta_count(self._tables.cat_padded, delta.added,
                                     delta.evicted, impl=self.impl,
                                     autotune=self.autotune)
                self._tables.apply_delta(deltas[:self._tables.n_tracked])
                derived = derive_frequent(self._tables,
                                          self.min_sup * self.window.size)
            delta_s = time.perf_counter() - td
            self._delta_seconds_accum += delta_s
            self._rows_since_remine += delta.n_added + delta.n_evicted
            if derived is None:
                new_levels = self._remine()
                remine_s = self._remine_seconds
                path = "remine_structural"
            elif self._staleness_triggered():
                new_levels = self._remine()
                remine_s = self._remine_seconds
                path = "remine_staleness"
            else:
                new_levels = derived
                path = "delta"

        if self.oracle_check and self.window.size > 0:
            oracle = mine(db_masks=self.window.contents(),
                          n_items=self.n_items, min_sup=self.min_sup,
                          algorithm=self.algorithm, runtime=self.runtime)
            assert levels_equal(new_levels, oracle.levels), \
                f"incremental state diverged from scratch mine ({path})"

        changed = not levels_equal(new_levels, self._published)
        self._published = new_levels
        refresh_s = 0.0
        if changed and self.refresh_rules:
            tr = time.perf_counter()
            with tracer.span("stream.refresh_rules"):
                ruleset = generate_ruleset(self.result(), self.min_confidence)
                self.engine.swap_rules(ruleset,
                                       warm_to=self.warm_queries or None)
            refresh_s = time.perf_counter() - tr

        upd_span.set(path=path, window=self.window.size,
                     n_frequent=self.n_frequent,
                     levels_changed=changed).close()
        rec = StreamUpdate(
            seq=len(self.updates), path=path,
            n_added=delta.n_added, n_evicted=delta.n_evicted,
            window_size=self.window.size,
            update_seconds=time.perf_counter() - t0,
            delta_seconds=delta_s, remine_seconds=remine_s,
            refresh_seconds=refresh_s, n_frequent=self.n_frequent,
            n_rules=self.engine.n_rules, levels_changed=changed)
        self.updates.append(rec)
        return rec
