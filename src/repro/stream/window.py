"""Device-resident transaction window: a bit-packed ring buffer of the live
transaction set under streaming load (DESIGN.md §8).

Transactions are packed to ``(W,)`` uint32 bitmasks on entry (``core/bitset``,
§2) and stored twice in the same ring layout:

* a host mirror — the exact source of truth for evicted-slab extraction and
  for the full re-mine fallback (``scatter_db`` wants host rows);
* a device ring — updated in place per micro-batch with one jitted scatter
  (donated buffer, pow2-bucketed row padding aimed at a dummy slot, so the
  streaming loop touches a handful of compiled shapes and ships only the
  O(delta) slab to the device, never the window).

Capacity is pow2-bucketed.  ``mode="sliding"`` evicts oldest-first when an
append overflows; ``mode="landmark"`` never evicts and grows the ring to the
next power of two instead.  Every mutation returns the exact added/evicted
bitmask slabs — precisely what ``kernels/delta_count.py`` needs to keep
tracked support counts current in O(delta).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitset import n_words, pack_itemsets
from repro.kernels.autotune import _bucket

MIN_CAPACITY = 64
MIN_WRITE_BUCKET = 32      # pow2 row padding of the per-update device scatter


@dataclasses.dataclass
class WindowDelta:
    """Exact bitmask slabs of one window mutation."""
    added: np.ndarray       # (A, W) uint32 transactions that entered
    evicted: np.ndarray     # (E, W) uint32 transactions that left

    @property
    def n_added(self) -> int:
        return self.added.shape[0]

    @property
    def n_evicted(self) -> int:
        return self.evicted.shape[0]


@functools.partial(jax.jit, donate_argnums=(0,))
def _ring_write(buf: jax.Array, rows: jax.Array, idx: jax.Array) -> jax.Array:
    """Scatter ``rows`` into ring slots ``idx`` (pad rows target the dummy
    slot — the extra last row — so row padding never clobbers live data)."""
    return buf.at[idx].set(rows)


class TransactionWindow:
    """Pow2-capacity ring buffer of bit-packed transactions.

    Args:
      n_items: item catalog size (fixes the mask width W).
      capacity: requested capacity; bucketed up to a power of two
        (≥ ``MIN_CAPACITY``).  In ``landmark`` mode this is only the initial
        allocation — the ring grows by doubling.
      mode: "sliding" (append evicts oldest-first on overflow) or
        "landmark" (append grows the ring, nothing auto-evicts).
    """

    MODES = ("sliding", "landmark")

    def __init__(self, n_items: int, capacity: int = 1024,
                 mode: str = "sliding"):
        if mode not in self.MODES:
            raise ValueError(f"unknown mode {mode!r}; options: {self.MODES}")
        self.n_items = n_items
        self.mode = mode
        self.W = n_words(n_items)
        self.capacity = max(MIN_CAPACITY, _bucket(capacity))
        self._start = 0
        self._size = 0
        self._host = np.zeros((self.capacity, self.W), np.uint32)
        # +1 dummy slot: padded scatter rows land there, not on live data
        self._dev = jnp.zeros((self.capacity + 1, self.W), jnp.uint32)

    def __len__(self) -> int:
        return self._size

    @property
    def size(self) -> int:
        return self._size

    # -- internals -------------------------------------------------------------

    def _slots(self, logical: np.ndarray) -> np.ndarray:
        return (self._start + logical) % self.capacity

    def _dev_write(self, rows: np.ndarray, slots: np.ndarray) -> None:
        """One jitted scatter: rows padded to a pow2 bucket → dummy slot."""
        n = rows.shape[0]
        if n == 0:
            return
        b = max(MIN_WRITE_BUCKET, _bucket(n))
        pad = b - n
        if pad:
            rows = np.concatenate(
                [rows, np.zeros((pad, self.W), np.uint32)], axis=0)
            slots = np.concatenate(
                [slots, np.full(pad, self.capacity, np.int64)])
        self._dev = _ring_write(self._dev, jnp.asarray(rows, jnp.uint32),
                                jnp.asarray(slots, jnp.int32))

    def _grow(self, need: int) -> None:
        cap = self.capacity
        while cap < need:
            cap *= 2
        if cap == self.capacity:
            return
        live = self.contents()
        self.capacity = cap
        self._host = np.zeros((cap, self.W), np.uint32)
        self._host[:live.shape[0]] = live
        self._start = 0
        self._dev = jnp.asarray(
            np.concatenate([self._host, np.zeros((1, self.W), np.uint32)]))

    def _pop(self, n: int, zero_device: bool = True) -> np.ndarray:
        """Evict the ``n`` oldest rows; returns their masks (host copy).

        ``zero_device=False`` skips the device zero-scatter — an overflowing
        append always rewrites every freed slot in its own scatter (the last
        ``n`` batch rows land exactly there), so the hot path pays one device
        dispatch per update, not two."""
        n = min(n, self._size)
        if n == 0:
            return np.zeros((0, self.W), np.uint32)
        slots = self._slots(np.arange(n))
        out = self._host[slots].copy()
        self._host[slots] = 0
        if zero_device:
            self._dev_write(np.zeros((n, self.W), np.uint32), slots)
        self._start = (self._start + n) % self.capacity
        self._size -= n
        return out

    # -- mutations -------------------------------------------------------------

    def append(self, transactions=None, *, masks=None) -> WindowDelta:
        """Append a micro-batch (item-id lists or pre-packed masks).

        Sliding mode evicts oldest-first to make room; landmark mode grows the
        ring.  Returns the exact net :class:`WindowDelta` — a batch larger
        than the sliding capacity keeps only its newest ``capacity`` rows, and
        the overflow never enters the window (so delta counting stays exact).
        """
        if masks is None:
            masks = pack_itemsets([list(t) for t in transactions],
                                  self.n_items)
        masks = np.asarray(masks, np.uint32).reshape(-1, self.W)
        B = masks.shape[0]
        if B == 0:
            return WindowDelta(masks, np.zeros((0, self.W), np.uint32))
        if self.mode == "landmark":
            self._grow(self._size + B)
            evicted = np.zeros((0, self.W), np.uint32)
        else:
            if B > self.capacity:        # only the newest rows can survive
                masks = masks[B - self.capacity:]
                B = masks.shape[0]
            # freed slots are a subset of this append's own write range
            # (size' + B fills the window up to exactly the old start), so
            # the device zero-scatter would be overwritten immediately
            evicted = self._pop(max(0, self._size + B - self.capacity),
                                zero_device=False)
        slots = self._slots(np.arange(self._size, self._size + B))
        self._host[slots] = masks
        self._dev_write(masks, slots)
        self._size += B
        return WindowDelta(masks.copy(), evicted)

    def evict(self, n: int) -> WindowDelta:
        """Explicitly evict the ``n`` oldest transactions (either mode)."""
        evicted = self._pop(n)
        return WindowDelta(np.zeros((0, self.W), np.uint32), evicted)

    # -- views -----------------------------------------------------------------

    def contents(self) -> np.ndarray:
        """(size, W) uint32 live transactions, oldest first (host copy)."""
        return self._host[self._slots(np.arange(self._size))].copy()

    def device_masks(self) -> jax.Array:
        """The (capacity, W) device ring (vacant slots are zero rows — they
        never inflate a non-empty candidate's count, §2 padding note)."""
        return self._dev[:self.capacity]
