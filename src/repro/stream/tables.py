"""Running per-level candidate count tables for incremental mining
(DESIGN.md §8).

After a full mine over the window, the tracked tables hold — per Apriori
level — a *superset* of the candidate set a from-scratch run would count,
with exact support counts: ``C_1`` = all singletons and ``C_{k+1}`` =
``apriori_gen(E_k)`` where ``E_k`` is the **margin-expanded** frequent set
``{c ∈ C_k : count ≥ (1 − margin)·min_count}``.  Frequent counts come from
the mining result; the *negative border* (tracked but infrequent) is counted
by one extra MapReduce job per level during the build.  The margin buys
headroom: a border itemset that drifts *above* threshold between re-mines
already has its supersets tracked, so near-threshold churn stays on the
O(delta) path instead of forcing a structural re-mine.  Between re-mines,
every window update adjusts all tracked counts with one O(delta) signed
counting dispatch (``kernels/delta_count.py``).

Exactness argument (:func:`derive_frequent`): the frequent levels of a
from-scratch mine are determined solely by the counts of the candidates it
generates.  Walking levels with the *current* counts, the cascade regenerates
``needed = apriori_gen(L'_{k-1})`` from the current frequent sets; whenever
every needed candidate is tracked, its exact count is known and the derived
levels are byte-identical to a from-scratch mine of the current window (both
arrays are the canonically lexsorted generation order filtered by the same
threshold).  A needed candidate that is *not* tracked — possible once a
border itemset drifts above threshold — means an unknown count: the cascade
reports structural drift and the miner falls back to a full re-mine, which is
always available and doubles as the equivalence oracle.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bitset import MaskIndex, singleton_masks
from repro.core.candidates import apriori_gen
from repro.core.phases import bucket_pad


@dataclasses.dataclass
class _Level:
    masks: np.ndarray        # (C, W) uint32 tracked candidates, canonical order
    counts: np.ndarray       # (C,) int64 exact supports over the window
    index: MaskIndex         # exact membership/lookup over ``masks``


class TrackedTables:
    """Per-level tracked candidates + running counts + one packed view.

    ``cat_padded`` is the bucket-padded concatenation of every tracked level
    (built once per re-mine) — the O(delta) counting dispatch runs over it and
    :meth:`apply_delta` scatters the signed deltas back per level.
    """

    def __init__(self, levels: dict):
        self.levels = {k: _Level(np.asarray(m, np.uint32),
                                 np.asarray(c, np.int64).copy(),
                                 MaskIndex(np.asarray(m, np.uint32)))
                       for k, (m, c) in sorted(levels.items())}
        parts = [lv.masks for lv in self.levels.values()]
        self.n_tracked = int(sum(p.shape[0] for p in parts))
        if parts:
            cat = np.concatenate(parts, axis=0)
            self.cat_padded = bucket_pad(cat)
        else:
            self.cat_padded = None

    @property
    def depth(self) -> int:
        return max(self.levels) if self.levels else 0

    def apply_delta(self, deltas: np.ndarray) -> None:
        """Scatter one (n_tracked,) signed delta vector into the per-level
        int64 running counts."""
        off = 0
        for lv in self.levels.values():
            n = lv.masks.shape[0]
            lv.counts += deltas[off:off + n].astype(np.int64)
            off += n
        assert off == self.n_tracked, (off, self.n_tracked)


def derive_frequent(tables: TrackedTables, min_count: float):
    """Derive the exact frequent levels of the current window from tracked
    counts, or return ``None`` on structural drift (unknown candidate needed).

    Returns the same shape as ``MiningResult.levels``: ``{k: (masks, counts)}``
    with empty levels dropped — byte-identical to a from-scratch ``mine()``
    on the window contents whenever it returns non-None.
    """
    if 1 not in tables.levels:
        return {}
    levels: dict = {}
    lv1 = tables.levels[1]
    keep = lv1.counts >= min_count
    L = lv1.masks[keep]
    if keep.any():
        levels[1] = (L, lv1.counts[keep])
    k = 2
    while L.shape[0] > 0:
        needed = apriori_gen(L, k - 1)
        if needed.shape[0] == 0:
            break
        lv = tables.levels.get(k)
        if lv is None:
            return None                       # deeper than anything tracked
        idx = lv.index.find(needed)
        if (idx < 0).any():
            return None                       # untracked candidate → re-mine
        counts = lv.counts[idx]
        keep = counts >= min_count
        L = needed[keep]
        if keep.any():
            levels[k] = (L, counts[keep])
        k += 1
    return levels


def build_tracked_levels(result_levels: dict, n_items: int, min_count: float,
                         margin: float, count_fn) -> dict:
    """Enumerate + count the tracked candidate sets after a full mine.

    Levels are built top-down: known counts are looked up from the mine's
    frequent levels, the per-level border is counted with ``count_fn(masks) →
    counts`` (one unfused MapReduce job per level), and the next level is
    generated from the margin-expanded set ``E_k`` (count ≥
    ``(1 − margin)·min_count``).  Since ``L'_k ⊆ E_k`` for any later frequent
    set that only churns within the margin, ``apriori_gen(L'_k) ⊆
    apriori_gen(E_k)`` (join of a subset is a subset; pruning against the
    smaller set is stricter) — which is exactly the cascade's coverage
    requirement.

    Returns ``{k: (masks, counts)}`` with exact counts everywhere.
    """
    tracked: dict = {}
    ext = max(0.0, (1.0 - margin)) * min_count
    k = 1
    cands = singleton_masks(n_items)
    while cands.shape[0]:
        counts = np.full(cands.shape[0], -1, np.int64)
        entry = result_levels.get(k)
        if entry is not None and np.asarray(entry[0]).shape[0] > 0:
            fmasks = np.asarray(entry[0], np.uint32)
            fcounts = np.asarray(entry[1], np.int64)
            idx = MaskIndex(fmasks).find(cands)
            counts[idx >= 0] = fcounts[idx[idx >= 0]]
        miss = counts < 0
        if miss.any():
            counts[miss] = np.asarray(count_fn(cands[miss]), np.int64)
        tracked[k] = (cands, counts)
        expanded = cands[counts >= ext]
        if expanded.shape[0] == 0:
            break
        cands = apriori_gen(expanded, k)
        k += 1
    return tracked


def levels_equal(a: dict, b: dict) -> bool:
    """Exact equality of two ``{k: (masks, counts)}`` level dicts."""
    if set(a) != set(b):
        return False
    for k in a:
        if not (np.array_equal(a[k][0], b[k][0])
                and np.array_equal(a[k][1], b[k][1])):
            return False
    return True
