"""CostModel: per-key affine cost fits in the measured-ops basis (DESIGN.md §9).

One :class:`AffineFit` per ``(device, impl, kind)`` key models the cost of a
job as

    t ≈ a + b · ops

with ``ops`` the job's work in the measured-ops basis ``roofline.count_job_ops``
defines (candidate-word comparisons for counting jobs; rule·query·word terms
for serving dispatches; window rows for re-mines).  The affine form is the
whole point: ``a`` is the per-job dispatch/setup overhead — the paper's
"job scheduling cost" that pass combining amortizes — and ``b`` the marginal
per-op counting cost that un-pruned candidates inflate.  Every adaptive
decision is a trade between the two.

Fits are accumulated online from observed timings (running sums — O(1) state
per key, no sample buffer), warm-started from and persisted to a JSON store
beside the autotune cache (``measure.costmodel_store``).  Predictions are
clamped monotone non-decreasing in ``ops`` (slope ≥ 0) so a wider phase is
never predicted cheaper than a narrower one at equal overhead.

Two defenses keep the fit honest on a live system:

* **decay** — running sums are multiplied by ``DECAY`` per observation
  (effective window ≈ 1/(1−DECAY) samples), so a stale regime (or an early
  bad sample) washes out instead of biasing the fit forever;
* **outlier rejection** — once calibrated, a sample more than
  ``OUTLIER_FACTOR``× the fit's own prediction is dropped: that signature is
  a one-off compile/jit spike, exactly the cost the steady-state model must
  *not* learn (a genuine regime change arrives as many moderate misses,
  which decay absorbs).
"""

from __future__ import annotations

import dataclasses
import math

from .measure import costmodel_store

# fits are noise-level below this many samples; predict() still answers (ratio
# estimate through the origin) but intercept-based overhead() stays None
MIN_AFFINE_SAMPLES = 3
DECAY = 0.9              # per-observation forgetting factor (~10-sample window)
OUTLIER_FACTOR = 8.0     # reject samples this far above the fit's prediction


@dataclasses.dataclass
class AffineFit:
    """Decayed running least-squares state for one cost key.

    ``n`` counts every accepted observation (calibration gating); ``sw`` is
    the *decayed* sample weight Σγⁱ the normal equations use, so the fit
    itself always reflects the recent regime."""
    n: int = 0
    sw: float = 0.0
    sx: float = 0.0
    sy: float = 0.0
    sxx: float = 0.0
    sxy: float = 0.0

    def observe(self, ops: float, seconds: float) -> None:
        x, y = float(ops), float(seconds)
        if not (math.isfinite(x) and math.isfinite(y)) or x <= 0 or y < 0:
            return
        if self.n >= MIN_AFFINE_SAMPLES:
            p = self.predict(x)
            if p is not None and p > 0 and y > OUTLIER_FACTOR * p:
                return              # compile/jit spike, not steady-state cost
        self.n += 1
        # decayed sums: sample weights fall off geometrically with age
        self.sw = DECAY * self.sw + 1.0
        self.sx = DECAY * self.sx + x
        self.sy = DECAY * self.sy + y
        self.sxx = DECAY * self.sxx + x * x
        self.sxy = DECAY * self.sxy + x * y

    def coeffs(self) -> tuple[float, float] | None:
        """(a, b) of t ≈ a + b·ops, clamped to a ≥ 0, b ≥ 0; None if unfit."""
        if self.n == 0 or self.sxx <= 0:
            return None
        ratio_b = max(self.sxy / self.sxx, 0.0)
        if self.n < MIN_AFFINE_SAMPLES:
            return (0.0, ratio_b)       # through-origin ratio estimate
        denom = self.sw * self.sxx - self.sx * self.sx
        if denom <= 0:                  # all samples at one ops value
            return (0.0, ratio_b)
        b = (self.sw * self.sxy - self.sx * self.sy) / denom
        a = (self.sy - b * self.sx) / self.sw
        if b < 0:                       # noise-dominated: keep monotonicity
            return (0.0, ratio_b)
        return (max(a, 0.0), b)

    def predict(self, ops: float) -> float | None:
        c = self.coeffs()
        if c is None:
            return None
        a, b = c
        return a + b * float(ops)

    def as_dict(self) -> dict:
        return {"n": self.n, "sw": self.sw, "sx": self.sx, "sy": self.sy,
                "sxx": self.sxx, "sxy": self.sxy}

    @classmethod
    def from_dict(cls, d: dict) -> "AffineFit":
        try:
            return cls(n=int(d["n"]), sw=float(d["sw"]), sx=float(d["sx"]),
                       sy=float(d["sy"]), sxx=float(d["sxx"]),
                       sxy=float(d["sxy"]))
        except (KeyError, TypeError, ValueError):
            return cls()


class CostModel:
    """Calibrated per-key cost predictor.

    Args:
      persist: warm-start fits from disk and write back after each
        observation (best-effort).  Tests and benchmarks that need a clean
        slate pass ``persist=False``.
    """

    SCHEMA = 2   # v2: decayed-weight fits (sw field); v1 stores are discarded

    def __init__(self, persist: bool = True):
        self.persist = persist
        self._fits: dict[str, AffineFit] = {}
        if persist:
            disk = costmodel_store().load()
            if disk.get("schema") == self.SCHEMA:
                for key, d in disk.get("fits", {}).items():
                    self._fits[key] = AffineFit.from_dict(d)

    def fit(self, key: str) -> AffineFit:
        if key not in self._fits:
            self._fits[key] = AffineFit()
        return self._fits[key]

    def observe(self, key: str, ops: float, seconds: float) -> None:
        self.fit(key).observe(ops, seconds)
        if self.persist:
            costmodel_store().save(
                {"schema": self.SCHEMA,
                 "fits": {k: f.as_dict() for k, f in self._fits.items()}})

    def predict(self, key: str, ops: float) -> float | None:
        """Predicted job seconds, or None when the key has no samples."""
        f = self._fits.get(key)
        return f.predict(ops) if f is not None else None

    def overhead(self, key: str) -> float | None:
        """Per-job fixed overhead (the fitted intercept ``a``), or None when
        the key lacks enough samples for an affine (vs ratio) fit."""
        f = self._fits.get(key)
        if f is None or f.n < MIN_AFFINE_SAMPLES:
            return None
        c = f.coeffs()
        return c[0] if c is not None else None

    def n_samples(self, key: str) -> int:
        f = self._fits.get(key)
        return f.n if f is not None else 0


_default: CostModel | None = None


def default_model() -> CostModel:
    """Process-wide shared model: every decision site calibrates the same
    fits, which is what makes the controller *one* controller."""
    global _default
    if _default is None:
        _default = CostModel()
    return _default
