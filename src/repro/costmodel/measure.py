"""Shared measurement + persistence layer (DESIGN.md §9).

Every adaptive decision in the stack ultimately rests on *measured elapsed
time* — the paper's ETDPC insight.  This module owns the two primitives the
measurers share so they cannot drift apart:

* :func:`time_once` — the warm-up + best-of-reps timing loop the block
  autotuner (``kernels/autotune.py``) and the cost-model benchmarks use;
* :func:`cache_dir` / :class:`JsonStore` — best-effort JSON persistence in
  the same directory as the autotune cache, so tunings and cost-model fits
  live (and ship) side by side;
* :func:`device_key` — the ``backend:device_kind`` identity that keys both
  caches.  Keying on ``jax.default_backend()`` alone silently reuses one
  machine's timings on another (two different GPUs are both ``"gpu"``); the
  concrete device kind disambiguates.
"""

from __future__ import annotations

import json
import os
import re


def cache_dir() -> str:
    """Directory shared by the autotune cache and the cost-model store."""
    return os.path.join(os.path.expanduser("~"), ".cache", "repro")


def device_key(backend: str | None = None) -> str:
    """``backend:device_kind`` cache identity for the current (or named)
    backend — e.g. ``cpu:cpu``, ``tpu:TPU-v5e``, ``gpu:NVIDIA-H100``."""
    import jax
    backend = backend or jax.default_backend()
    try:
        kind = jax.devices(backend)[0].device_kind
    except Exception:
        kind = "unknown"
    kind = re.sub(r"[^A-Za-z0-9_.]+", "-", str(kind)).strip("-") or "unknown"
    return f"{backend}:{kind}"


def time_once(fn, reps: int = 2, clock=None) -> float:
    """Best-of-``reps`` wall time of ``fn()`` after one warm-up call.

    The warm-up run pays compile cost; the timed runs block on the result, so
    the number is steady-state device time + dispatch overhead — exactly what
    the cost model wants to fit and the autotuner wants to rank.

    ``clock`` follows the injectable-clock contract (DESIGN.md §13): any
    object with ``now() -> float`` seconds; default the monotonic wall
    clock.  Tests pass :class:`repro.obs.clock.FakeClock` to script timings.
    """
    import jax
    if clock is None:
        clock = _monotonic_clock()
    out = fn()                      # warm-up: compile + first run
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = clock.now()
        jax.block_until_ready(fn())
        best = min(best, clock.now() - t0)
    return best


def _monotonic_clock():
    from repro.obs.clock import MonotonicClock
    return MonotonicClock()


class JsonStore:
    """Best-effort persisted JSON dict (atomic replace; errors never raise).

    The in-memory dict is authoritative for the process; disk is a warm-start
    for the next one — the same contract as the autotune disk cache.
    """

    def __init__(self, path: str):
        self.path = path

    def load(self) -> dict:
        try:
            with open(self.path) as f:
                out = json.load(f)
            return out if isinstance(out, dict) else {}
        except (OSError, ValueError):
            return {}

    def save(self, store: dict) -> None:
        try:
            os.makedirs(os.path.dirname(self.path), exist_ok=True)
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(store, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            pass


def costmodel_store() -> JsonStore:
    """The persisted cost-model fit store (override: REPRO_COSTMODEL_CACHE;
    ``REPRO_COSTMODEL_CACHE=""`` disables persistence via a /dev/null-ish
    path that simply fails to write)."""
    env = os.environ.get("REPRO_COSTMODEL_CACHE")
    path = env if env else os.path.join(cache_dir(), "costmodel.json")
    return JsonStore(path)
