"""CostController: the stack's three ETDPC-style decisions, one cost model
(DESIGN.md §9).

The paper's deepest idea — decide how much work to fuse into the next phase
from the *measured elapsed time* of preceding ones — used to live in four
divergent copies (pass-combining policies, the stream re-mine trigger, the
serving fusion policy, the autotuner's private timing loop), each with its
own ad-hoc thresholds.  The controller puts them behind one calibrated
:class:`~repro.costmodel.model.CostModel` and exposes the decision
primitives the stack needs:

* :meth:`choose_width`   — predicted cost of ``w`` fused passes vs ``w``
  separate jobs → the ``measured`` pass-combining policy (the paper-faithful
  SPC…Optimized-ETDPC transcriptions stay untouched as baselines);
* :meth:`choose_mesh`    — the elastic per-level repartitioning decision
  (DESIGN.md §11): price the next fused phase's (C, T) extents under every
  ``(n_data, n_cand)`` factorization of the device count and pick the
  cheapest split, charging a measured re-scatter penalty (with hysteresis)
  when it differs from the current one;
* :meth:`should_rebalance` — price the LPT width-balance of the database
  (static straggler mitigation) against its measured host cost: rebalance
  only when the predicted per-shard work skew, integrated over the expected
  counting jobs, exceeds what the re-pack costs;
* :meth:`should_remine`  — predicted full-remine cost at the *current*
  window size vs accumulated delta-counting cost (StreamMiner);
* :meth:`choose_fusion`  — serving micro-batch depth under a latency budget
  (RuleServeEngine / ServeEngine).

Counting-job fits are calibrated in the **per-shard** ops basis: ``ops =
count_job_ops(C/n_cand, T/n_data, W) + transfer`` — the work one device of
the current mesh actually performs — so one fit prices alternative splits
of the same job, which is what makes :meth:`choose_mesh` possible.
Collective/serialization overheads of a split fold into the fit's intercept
as soon as jobs on that mesh are observed (decayed window, so a re-layout
re-calibrates within a few phases).

Every decision is appended to :attr:`decisions` — what was predicted, what
was chosen, and (once known) what was measured — the per-decision telemetry
``launch/report.py`` renders.
"""

from __future__ import annotations

import dataclasses
import math

from repro.obs.metrics import get_registry
from repro.obs.trace import current_tracer

from repro.roofline import XFER_OPS_PER_BYTE, count_job_ops

from .measure import device_key
from .model import CostModel, default_model

MAX_DECISIONS = 4096     # telemetry ring: keep the newest decisions


@dataclasses.dataclass
class Decision:
    """One adaptive decision: prediction → choice → (later) measurement."""
    site: str                 # "pass_width" | "speculate" | "remine" |
                              # "serve_fusion" | "decode_fusion"
    key: str                  # cost-model key consulted
    predicted: dict           # option → predicted seconds (or {"cost": x})
    chosen: object            # the decision taken
    measured: float | None = None   # realized seconds, filled by observe_*
    # live view of this decision inside an exported trace (DESIGN.md §13);
    # None when tracing is off
    trace_args: dict | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def as_dict(self) -> dict:
        return {"site": self.site, "key": self.key, "chosen": self.chosen,
                "predicted": {str(k): float(v)
                              for k, v in self.predicted.items()},
                "measured": self.measured}

    def predicted_chosen(self) -> float | None:
        """The predicted cost of the option actually taken (if priced)."""
        for k in (self.chosen, str(self.chosen)):
            if k in self.predicted:
                return float(self.predicted[k])
        return None

    def __setattr__(self, name, value):
        object.__setattr__(self, name, value)
        if name == "measured" and value is not None:
            # observe_* backfills realized cost after the fact; mirror it
            # into the trace event's (shared, mutable) args so exported
            # traces carry predicted-vs-measured residuals
            args = getattr(self, "trace_args", None)
            if args is not None:
                args["measured"] = float(value)
                pred = self.predicted_chosen()
                if pred is not None:
                    args["residual"] = float(value) - pred


class CostController:
    """Decision engine over a (usually shared) :class:`CostModel`.

    Args:
      model: the calibrated fit store; defaults to the process-wide shared
        model so every site contributes to — and benefits from — the same
        calibration.
      max_width: widest phase :meth:`choose_width` may pick (the paper's
        drivers never exceed α=3; the measured policy keeps that ceiling by
        default but it is a knob, not a transcription).
      spec_hide_fraction: speculate only when the predicted in-flight count
        time is at least this fraction of the last measured speculative-join
        cost — below it there is no window to hide the join in.
    """

    def __init__(self, model: CostModel | None = None, *, max_width: int = 3,
                 spec_hide_fraction: float = 0.25,
                 backend: str | None = None):
        self.model = model if model is not None else default_model()
        self.max_width = max(int(max_width), 1)
        self.spec_hide_fraction = spec_hide_fraction
        self.device = device_key(backend)
        self.decisions: list[Decision] = []
        # mining count-job context (set by drivers.mine before the loop)
        self._count_impl = "default"
        self._count_txns = 1
        self._count_words = 1
        self._count_data_shards = 1
        self._count_cand_shards = 1
        self._last_spec_seconds: float | None = None

    # -- telemetry -------------------------------------------------------------

    def _record(self, dec: Decision) -> Decision:
        self.decisions.append(dec)
        if len(self.decisions) > MAX_DECISIONS:
            del self.decisions[:len(self.decisions) - MAX_DECISIONS]
        get_registry().counter("costmodel.decisions", site=dec.site).inc()
        tracer = current_tracer()
        if tracer.enabled:
            # the event's args dict stays live: Decision.__setattr__ writes
            # measured/residual into it when observe_* backfills
            args = dec.as_dict()
            pred = dec.predicted_chosen()
            if pred is not None:
                args["predicted_chosen"] = pred
            dec.trace_args = args
            tracer.event(f"decision.{dec.site}", args=args)
        return dec

    def decision_rows(self, since: int = 0) -> list:
        """Decisions (as dicts) appended at index ``since`` or later."""
        return [d.as_dict() for d in self.decisions[since:]]

    # -- count jobs (mining phase loop) ----------------------------------------

    def set_count_context(self, *, n_txns: int, n_words: int, impl: str,
                          n_data_shards: int = 1,
                          n_cand_shards: int = 1) -> None:
        """Pin the per-run constants of the counting-ops basis (DESIGN.md §9):
        within one mine() run at a fixed mesh split, job work varies only
        with candidate count.  The shard counts put observations in the
        per-shard basis (DESIGN.md §11) — call again after a repartition."""
        self._count_txns = max(int(n_txns), 1)
        self._count_words = max(int(n_words), 1)
        self._count_impl = impl
        self._count_data_shards = max(int(n_data_shards), 1)
        self._count_cand_shards = max(int(n_cand_shards), 1)

    @property
    def count_key(self) -> str:
        return f"{self.device}/{self._count_impl}/count"

    @staticmethod
    def est_count_bytes(n_candidates: float) -> float:
        """Estimated device→host result bytes of one fused counting job:
        the packed keep mask (C/8 bytes) plus filtered int32 counts (4·C).
        Used when the caller has no measured transfer delta (predictions)."""
        return 4.125 * max(float(n_candidates), 1.0)

    def _count_ops(self, n_candidates: float,
                   bytes_to_host: float | None = None,
                   split: tuple[int, int] | None = None) -> float:
        """Per-shard ops of one counting job on an ``(n_data, n_cand)`` mesh.

        Compute is C/n_cand candidates against T/n_data transactions; the
        device→host result transfer is global (it crosses the host boundary
        once whatever the split).  Two transfer terms *do* depend on the
        split — they are what makes equal-product factorizations price
        differently in :meth:`choose_mesh` (raw compute C·T·W/devices is
        split-invariant): the per-device candidate payload placement
        (4·W·C/n_cand bytes: candidate sharding shrinks it, the lever that
        favors all-cand when |C_k| explodes) and the psum over ``data``
        (≈ 2·(n_data−1)/n_data ring-allreduce passes over the per-shard
        result bytes: zero at n_data=1, the lever against wide data splits
        on small jobs)."""
        if bytes_to_host is None:
            bytes_to_host = self.est_count_bytes(n_candidates)
        dd, dc = split if split is not None else (
            self._count_data_shards, self._count_cand_shards)
        dd, dc = max(dd, 1), max(dc, 1)
        c_shard = max(int(math.ceil(max(n_candidates, 1) / dc)), 1)
        t_shard = max(self._count_txns // dd, 1)
        payload = 4.0 * self._count_words * c_shard
        psum = 2.0 * (dd - 1) / dd * self.est_count_bytes(c_shard)
        return count_job_ops(c_shard, t_shard, self._count_words,
                             bytes_to_host=bytes_to_host) \
            + XFER_OPS_PER_BYTE * (payload + psum)

    def observe_count(self, n_candidates: int, seconds: float,
                      bytes_to_host: float | None = None) -> None:
        """Calibrate from one completed counting job (any policy, any run).

        ``bytes_to_host`` is the job's measured device→host result traffic
        (e.g. a ``RuntimeStats.bytes_to_host`` delta); omitted, the fused-job
        estimate keeps observation and prediction in the same basis."""
        self.model.observe(self.count_key,
                           self._count_ops(n_candidates, bytes_to_host),
                           seconds)
        # realized time goes to the newest unmeasured width/mesh decision
        for site in ("pass_width", "mesh_split"):
            for d in reversed(self.decisions):
                if d.site == site:
                    if d.measured is None:
                        d.measured = float(seconds)
                    break

    def predict_count(self, n_candidates: int,
                      bytes_to_host: float | None = None) -> float | None:
        return self.model.predict(self.count_key,
                                  self._count_ops(n_candidates,
                                                  bytes_to_host))

    def choose_width(self, prev, prev2) -> float | None:
        """Pick the candidate budget α minimizing predicted cost per level.

        ``prev``/``prev2`` are PhaseStats-shaped (n_candidates,
        n_frequent_last, elapsed).  The chosen α executes with the drivers'
        *budget* semantics — candidate generation stops once the fused phase
        has spent α·|L| candidates — so the un-pruned tail can never explode
        past what the model priced in: a fused phase costs at most one job
        overhead ``a`` plus ``b``·ops(α·|L|), whatever the lattice does.
        The number of levels that budget covers is extrapolated from the
        observed |C| trajectory; minimizing ``(a + b·ops)/levels`` trades
        exactly the paper's pair — saved job setups against un-pruned
        counting work.  Returns α, or None when the model is uncalibrated
        (caller falls back to the paper's ETDPC table).
        """
        fit = self.model.fit(self.count_key)
        coeffs = fit.coeffs()
        if coeffs is None or prev is None:
            return None
        a, b = coeffs
        c_next = max(prev.n_frequent_last, 1)
        # per-level candidate estimates ĉ_j for the next fused phase
        if prev2 is None:
            # deciding right after Job1: level 2 is the complete pair join
            # over |L1| frequent items, and each further *un-pruned* level of
            # a fused phase joins the complete level below it — so level 2+j
            # is exactly C(|L1|, 2+j) candidates.  This is what makes fusing
            # here dangerous (the binomial mid-levels dwarf the pruned
            # trajectory ETDPC's width-1 phases would see) and the estimate
            # prices that in exactly.
            est = [float(min(math.comb(c_next, 2 + j), 10 ** 15))
                   for j in range(self.max_width)]
            max_w = self.max_width
        else:
            growth = prev.n_candidates / max(prev2.n_candidates, 1)
            growth = min(max(growth, 0.25), 16.0)
            c0 = max(prev.n_candidates * growth, 1.0)
            max_w = self.max_width
            est = [c0 * growth ** j for j in range(max_w)]
        cum = [sum(est[:j + 1]) for j in range(max_w)]
        predicted: dict = {}
        best_w, best_per_level = 1, float("inf")
        for w in range(1, max_w + 1):
            # a fused phase covering w levels counts all of them in one job
            cost = a + b * self._count_ops(cum[w - 1])
            predicted[w] = cost
            if cost / w < best_per_level:
                best_per_level, best_w = cost / w, w
        self._record(Decision("pass_width", self.count_key, predicted,
                              best_w))
        if best_w == 1:
            return 1.0
        # budget that executes exactly best_w levels *on these estimates*:
        # the drivers append a level, then stop once the cumulative count
        # exceeds α·|L| — so any α with S_{w-2} ≤ α·|L| < S_{w-1} covers w
        # levels; the midpoint is robust to estimate noise on both sides.
        # If the real lattice outgrows the estimates, generation stops
        # early and the overshoot is bounded by the one level the paper's
        # budget drivers also risk.
        alpha = (cum[best_w - 2] + cum[best_w - 1]) / (2.0 * c_next)
        return max(alpha, 1.0)

    # -- elastic mesh repartitioning (drivers, DESIGN.md §11) ------------------

    @property
    def repartition_key(self) -> str:
        return f"{self.device}/{self._count_impl}/scatter"

    def observe_repartition(self, n_txns: int, n_words: int,
                            seconds: float) -> None:
        """Calibrate the re-layout penalty from one measured (re-)scatter —
        host re-pack plus device placement, proportional to database bytes."""
        self.model.observe(self.repartition_key,
                           max(int(n_txns), 1) * max(int(n_words), 1), seconds)

    def predict_repartition(self, n_txns: int, n_words: int) -> float | None:
        return self.model.predict(self.repartition_key,
                                  max(int(n_txns), 1) * max(int(n_words), 1))

    def choose_mesh(self, est_candidates: int, *, n_devices: int,
                    current: tuple[int, int] | None = None,
                    hysteresis: float = 0.15) -> tuple[int, int] | None:
        """Pick the ``(n_data, n_cand)`` split minimizing the next fused
        phase's predicted cost (DESIGN.md §11).

        Every factorization of ``n_devices`` is priced at the per-shard ops
        the split would give this phase's (C, T) extents: all-data splits
        divide the transaction work, all-cand splits divide the candidate
        work (candidate counts explode between k=2 and k=3, so a static
        split always loses one regime).  A split different from ``current``
        is charged the measured re-scatter penalty and must beat the current
        split by ``hysteresis`` (fractional) on top of it — re-layouts are
        never free, so ping-ponging on noise is priced out.  Returns the
        chosen split, or None when the model is uncalibrated (caller keeps
        the current mesh).
        """
        if n_devices <= 1:
            return None
        coeffs = self.model.fit(self.count_key).coeffs()
        if coeffs is None:
            return None
        a, b = coeffs
        penalty = self.predict_repartition(self._count_txns,
                                           self._count_words) or 0.0
        predicted: dict = {}
        best, best_t = None, float("inf")
        cur_t = None
        for dd in range(1, n_devices + 1):
            if n_devices % dd:
                continue
            split = (dd, n_devices // dd)
            t = a + b * self._count_ops(est_candidates, split=split)
            predicted[f"{split[0]}x{split[1]}"] = t
            if current is not None and split == current:
                cur_t = t
            elif current is not None:
                t += penalty
            if t < best_t:
                best, best_t = split, t
        if current is not None and best != current and cur_t is not None:
            if best_t > (1.0 - hysteresis) * cur_t:
                best, best_t = current, cur_t     # not worth the re-layout
        self._record(Decision("mesh_split", self.count_key, predicted,
                              f"{best[0]}x{best[1]}"))
        return best

    # -- LPT shard balance (drivers, DESIGN.md §11) ----------------------------

    @property
    def rebalance_key(self) -> str:
        return f"{self.device}/host/rebalance"

    def observe_rebalance(self, n_txns: int, seconds: float) -> None:
        """Calibrate from one measured LPT width-balance re-pack."""
        self.model.observe(self.rebalance_key, max(int(n_txns), 1), seconds)

    def should_rebalance(self, shard_loads, *, est_candidates: int,
                         est_jobs: int = 3) -> bool:
        """Enable the static LPT width balance only when it pays for itself.

        ``shard_loads`` are the per-shard total transaction widths an
        unbalanced contiguous split would produce (the per-mapper work
        proxy).  The predicted straggler waste is the skew fraction
        ``max/mean − 1`` of one predicted counting job, integrated over
        ``est_jobs`` expected jobs; the cost side is the calibrated host
        re-pack time (a cheap O(N log N) estimate until first measured).
        """
        loads = [float(x) for x in shard_loads]
        if len(loads) < 2 or sum(loads) <= 0:
            return False
        mean = sum(loads) / len(loads)
        skew = max(loads) / mean - 1.0
        t_job = self.predict_count(est_candidates)
        if t_job is None:
            return False                    # uncalibrated: keep the default
        waste = skew * t_job * max(int(est_jobs), 1)
        cost = self.model.predict(self.rebalance_key, self._count_txns)
        if cost is None:
            cost = 2e-8 * self._count_txns  # ~numpy argsort+take per row
        fire = waste > cost
        self._record(Decision("rebalance", self.rebalance_key,
                              {"straggler_waste": waste, "rebalance": cost},
                              fire))
        return fire

    # -- speculative-join sizing (drivers) -------------------------------------

    def observe_spec(self, seconds: float) -> None:
        """Record the measured cost of one speculative next-phase join."""
        if seconds > 0:
            self._last_spec_seconds = float(seconds)

    def should_speculate(self, est_candidates: int) -> bool:
        """Speculate only when the predicted count-job time leaves a window
        worth hiding the join in.  Permissive by default: with no calibration
        or no measured join cost yet, speculate (the pre-refactor behavior —
        the survival-rate gate in ``drivers.mine`` still applies first)."""
        predicted = self.predict_count(est_candidates)
        if predicted is None or self._last_spec_seconds is None:
            return True
        ok = predicted >= self.spec_hide_fraction * self._last_spec_seconds
        self._record(Decision(
            "speculate", self.count_key,
            {"count_job": predicted, "join": self._last_spec_seconds}, ok,
            measured=predicted))
        return ok

    # -- stream re-mine trigger (StreamMiner) ----------------------------------

    @property
    def remine_key(self) -> str:
        return f"{self.device}/{self._count_impl}/remine"

    def observe_remine(self, window_rows: int, seconds: float) -> None:
        """Calibrate from one completed full re-mine of ``window_rows``."""
        self.model.observe(self.remine_key, max(int(window_rows), 1), seconds)

    def predict_remine(self, window_rows: int) -> float | None:
        """Predicted full-remine seconds at the *current* window size — the
        cold-start fix: a tiny init-time mine no longer freezes the estimate
        (ops basis = window rows, so one sample already extrapolates
        proportionally as the window grows)."""
        return self.model.predict(self.remine_key, max(int(window_rows), 1))

    def should_remine(self, *, drift: float, staleness_seconds: float,
                      window_rows: int, staleness_factor: float,
                      fallback_seconds: float | None = None) -> bool:
        """ETDPC-style opportunistic trigger: re-mine when the accumulated
        delta-path cost, scaled by window churn, exceeds the predicted cost
        of re-mining now."""
        predicted = self.predict_remine(window_rows)
        if predicted is None:
            predicted = fallback_seconds
        if predicted is None or window_rows <= 0:
            return False
        fire = drift * staleness_seconds > staleness_factor * predicted
        self._record(Decision(
            "remine", self.remine_key,
            {"remine": predicted, "accumulated": drift * staleness_seconds},
            fire))
        return fire

    # -- serving micro-batch fusion (RuleServeEngine / ServeEngine) ------------

    def serve_key(self, kind: str = "rule_serve") -> str:
        return f"{self.device}/{kind}/dispatch"

    def observe_serve(self, work_per_unit: float, n_units: int,
                      seconds: float, kind: str = "rule_serve") -> None:
        """Calibrate from one serving dispatch (``n_units`` fused units of
        ``work_per_unit`` ops each — queries·rules·words for rule serving,
        batch rows for decode steps)."""
        self.model.observe(self.serve_key(kind),
                           max(work_per_unit, 1.0) * max(int(n_units), 1),
                           seconds)
        for d in reversed(self.decisions):
            if d.site.endswith("_fusion"):
                if d.measured is None:
                    d.measured = float(seconds)
                break

    def should_admit(self, *, work: float, latency_slo_s: float,
                     backlog_s: float = 0.0,
                     kind: str = "rule_serve") -> tuple[bool, Decision]:
        """SLO admission for one serving query (DESIGN.md §12).

        Predicted sojourn = queue backlog already committed to the device
        (``backlog_s``, virtual busy time ahead of this query) plus the
        calibrated dispatch-time prediction for ``work`` ops.  Admit iff the
        sojourn fits ``latency_slo_s``.  Permissive when uncalibrated — with
        no fit there is no honest prediction, and the first dispatches *are*
        the calibration.  Returns ``(admit, decision)``; the decision is
        recorded under site ``"admission"`` so ``report.py --decisions``
        renders shed telemetry next to mining decisions, and the caller
        backfills ``decision.measured`` with the realized latency.
        """
        key = self.serve_key(kind)
        predicted = (self.model.predict(key, max(work, 1.0))
                     if self.model.n_samples(key) else None)
        if predicted is None:
            dec = self._record(Decision(
                "admission", key, {"slo": latency_slo_s}, True))
            return True, dec
        sojourn = float(backlog_s) + float(predicted)
        admit = sojourn <= latency_slo_s
        dec = self._record(Decision(
            "admission", key,
            {"sojourn": sojourn, "slo": latency_slo_s}, admit))
        return admit, dec

    def choose_fusion(self, *, work_per_unit: float, queued: int,
                      max_fuse: int, latency_budget_s: float | None = None,
                      kind: str = "rule_serve") -> int | None:
        """Units (query batches / decode steps) to fuse into one dispatch.

        With a latency budget: the widest fusion whose predicted dispatch
        time fits the budget (always at least 1 — a budget no single unit
        meets degrades to per-unit dispatch, the honest floor).  Without one:
        fuse maximally — per-unit cost ``(a + b·f·ops)/f`` is non-increasing
        in ``f``, so the only reason to hold back is latency.  Returns None
        when the model is uncalibrated (caller falls back to its policy).
        """
        key = self.serve_key(kind)
        if self.model.n_samples(key) == 0:
            return None
        cap = max(min(int(queued), int(max_fuse)), 1)
        predicted: dict = {}
        chosen = cap
        if latency_budget_s is not None:
            chosen = 1
            for f in range(1, cap + 1):
                t = self.model.predict(key, max(work_per_unit, 1.0) * f)
                predicted[f] = t
                if t is not None and t <= latency_budget_s:
                    chosen = f
        else:
            predicted[cap] = self.model.predict(
                key, max(work_per_unit, 1.0) * cap)
        self._record(Decision(f"{kind}_fusion"
                              if not kind.endswith("_fusion") else kind,
                              key, {str(k): v for k, v in predicted.items()
                                    if v is not None}, chosen))
        return chosen
