"""One measured-cost controller for every adaptive decision (DESIGN.md §9).

The subsystem has three layers:

* ``measure``    — shared timing loop + JSON persistence + device identity
  (also used by the block autotuner, so tunings and fits share one cache
  directory and one device-keying scheme);
* ``model``      — :class:`CostModel`: per-(device, impl, kind) affine fits
  ``t ≈ a + b·ops`` in the measured-ops basis ``roofline.count_job_ops``
  defines, calibrated online and persisted;
* ``controller`` — :class:`CostController`: the decision primitives
  (``choose_width`` / ``should_remine`` / ``choose_fusion`` /
  ``should_speculate``) plus per-decision telemetry.

Consumers: ``core/policy.MeasuredPolicy`` (pass combining),
``core/drivers.mine`` (calibration + speculative-join sizing),
``stream/miner.StreamMiner`` (re-mine trigger),
``serving/rules_engine.RuleServeEngine`` and ``serving/engine.ServeEngine``
(micro-batch fusion under a latency budget).
"""

from .controller import CostController, Decision
from .measure import JsonStore, cache_dir, costmodel_store, device_key, time_once
from .model import AffineFit, CostModel, default_model

__all__ = [
    "AffineFit", "CostModel", "CostController", "Decision", "JsonStore",
    "cache_dir", "costmodel_store", "default_model", "device_key",
    "time_once",
]
