"""Encoder–decoder backbone (whisper-small).

The audio frontend (log-mel + convs) is a STUB: the encoder consumes
precomputed frame embeddings (B, enc_seq, d_model) from ``input_specs``.
Positions are learned-absolute (``use_rope=False`` in the config).
Decoder layers: causal self-attention + cross-attention over encoder output
+ MLP.  Cross K/V are computed once at prefill and cached.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (attention_apply, attention_decode, attention_init,
                     dense_init, embed_init, embed_lookup, mlp_apply,
                     mlp_init, pdtype, rmsnorm, rmsnorm_init)
from .transformer import decoder_logits


def encdec_init(key, cfg):
    ks = jax.random.split(key, 8)
    params, axes = {}, {}
    params["embed"], axes["embed"] = embed_init(ks[0], cfg)
    params["dec_pos"] = jnp.zeros((cfg.max_seq_len, cfg.d_model), pdtype(cfg))
    axes["dec_pos"] = (None, "embed")
    params["enc_pos"] = jnp.zeros((cfg.enc_seq, cfg.d_model), pdtype(cfg))
    axes["enc_pos"] = (None, "embed")
    if not cfg.tie_embeddings:
        params["out_head"], axes["out_head"] = dense_init(
            ks[1], (cfg.d_model, cfg.vocab_padded), ("embed", "vocab"), dtype=pdtype(cfg))
    params["enc_final_norm"], axes["enc_final_norm"] = rmsnorm_init(cfg)
    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg)

    def enc_block_init(k):
        k1, k2 = jax.random.split(k)
        p, a = {}, {}
        p["norm1"], a["norm1"] = rmsnorm_init(cfg)
        p["attn"], a["attn"] = attention_init(k1, cfg)
        p["norm2"], a["norm2"] = rmsnorm_init(cfg)
        p["mlp"], a["mlp"] = mlp_init(k2, cfg)
        return p, a

    def dec_block_init(k):
        k1, k2, k3 = jax.random.split(k, 3)
        p, a = {}, {}
        p["norm1"], a["norm1"] = rmsnorm_init(cfg)
        p["attn"], a["attn"] = attention_init(k1, cfg)
        p["norm_x"], a["norm_x"] = rmsnorm_init(cfg)
        p["cross"], a["cross"] = attention_init(k2, cfg, cross=True)
        p["norm2"], a["norm2"] = rmsnorm_init(cfg)
        p["mlp"], a["mlp"] = mlp_init(k3, cfg)
        return p, a

    def stack(k, n, initfn):
        keys = jax.random.split(k, n)
        stacked = jax.vmap(lambda kk: initfn(kk)[0])(keys)
        _, a = initfn(k)
        a = jax.tree.map(lambda t: ("layers",) + t, a,
                         is_leaf=lambda t: isinstance(t, tuple))
        return stacked, a

    params["enc_blocks"], axes["enc_blocks"] = stack(
        ks[2], cfg.n_encoder_layers, enc_block_init)
    params["dec_blocks"], axes["dec_blocks"] = stack(
        ks[3], cfg.n_layers, dec_block_init)
    return params, axes


def encode(params, frame_embeds, cfg, ctx):
    """frame_embeds: (B, enc_seq, D) → encoder output (B, enc_seq, D)."""
    x = frame_embeds.astype(pdtype(cfg)) + params["enc_pos"][None]
    if ctx is not None:
        x = ctx.constrain(x, ("batch", "act_seq", None))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def block(x, p):
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        out, _ = attention_apply(p["attn"], h, cfg, ctx, positions,
                                 causal=False, rope=False)
        x = x + out
        x = x + mlp_apply(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps))
        if ctx is not None:
            x = ctx.constrain(x, ("batch", "act_seq", None))
        return x, None

    blk = jax.checkpoint(block) if cfg.remat else block
    x, _ = jax.lax.scan(blk, x, params["enc_blocks"])
    return rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)


def decode_train(params, tokens, enc_out, cfg, ctx,
                 return_caches: bool = False, cache_len: int | None = None):
    """Teacher-forced decoder pass. Returns final hidden (B, S, D) [+caches]."""
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens) + params["dec_pos"][None, :S]
    if ctx is not None:
        x = ctx.constrain(x, ("batch", "act_seq", None))
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    enc_positions = jnp.broadcast_to(
        jnp.arange(enc_out.shape[1])[None, :], (B, enc_out.shape[1]))

    def block(x, p):
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        out, (k, v) = attention_apply(p["attn"], h, cfg, ctx, positions,
                                      causal=True, rope=False)
        x = x + out
        hx = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        out, (ck, cv) = attention_apply(p["cross"], hx, cfg, ctx, positions,
                                        causal=False, kv_x=enc_out,
                                        kv_positions=enc_positions, rope=False)
        x = x + out
        x = x + mlp_apply(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps))
        if ctx is not None:
            x = ctx.constrain(x, ("batch", "act_seq", None))
        caches = {"k": k, "v": v, "cross_k": ck, "cross_v": cv} if return_caches else {}
        return x, caches

    blk = jax.checkpoint(block) if cfg.remat else block
    x, caches = jax.lax.scan(blk, x, params["dec_blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if not return_caches:
        return x
    cache_len = cache_len or S
    pad = [(0, 0), (0, 0), (0, cache_len - S), (0, 0), (0, 0)]
    caches = {"k": jnp.pad(caches["k"], pad), "v": jnp.pad(caches["v"], pad),
              "cross_k": caches["cross_k"], "cross_v": caches["cross_v"]}
    return x, caches


def encdec_decode_step(params, caches, token, pos, cfg, ctx):
    """token: (B,1); pos: (B,). caches: dict with k/v (L,B,Smax,H,hd) and
    cross_k/cross_v (L,B,enc_seq,H,hd).  Returns (logits (B,Vp), new caches)."""
    x = embed_lookup(params["embed"], token) + params["dec_pos"][pos][:, None, :]

    def block(x, inp):
        p, ck, cv, xk, xv = inp
        h = rmsnorm(p["norm1"], x, cfg.norm_eps)
        out, nk, nv = attention_decode(p["attn"], h, cfg, ctx, ck, cv, pos)
        x = x + out
        hx = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        # cross attention over fixed encoder K/V (no update, no causal mask)
        B = x.shape[0]
        q = jnp.einsum("bsd,dhk->bshk", hx, p["cross"]["wq"])[:, 0]
        s = jnp.einsum("bhd,bthd->bht", q, xk).astype(jnp.float32)
        s = s / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bht,bthd->bhd", w, xv.astype(jnp.float32)).astype(x.dtype)
        out = jnp.einsum("bhk,hkd->bd", o, p["cross"]["wo"])[:, None, :]
        x = x + out
        x = x + mlp_apply(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps))
        return x, (nk, nv)

    x, (nks, nvs) = jax.lax.scan(
        block, x, (params["dec_blocks"], caches["k"], caches["v"],
                   caches["cross_k"], caches["cross_v"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = decoder_logits(params, x, cfg, ctx)[:, 0, :]
    new_caches = dict(caches, k=nks, v=nvs)
    return logits, new_caches


def encdec_empty_caches(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16):
    L = cfg.n_layers
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((L, batch, cache_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((L, batch, cache_len, cfg.n_kv_heads, hd), dtype),
        "cross_k": jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv_heads, hd), dtype),
    }


def encdec_cache_axes(cfg):
    kv = ("layers", "cache_batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": kv, "v": kv,
            "cross_k": ("layers", "cache_batch", None, "kv_heads", "head_dim"),
            "cross_v": ("layers", "cache_batch", None, "kv_heads", "head_dim")}
