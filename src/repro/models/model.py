"""Model facade: family dispatch, abstract init, input specs, loss/prefill/decode.

``Model`` is the single public entry point consumed by the trainer, the serving
engine, and the dry-run launcher.  All heavy code lives in transformer.py /
encdec.py; this module wires families together and owns the ShardCtx used to
place sharding constraints on activations.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import sharding
from repro.compat import shard_map
from repro.configs.base import ModelConfig, ShapeConfig

from . import encdec, transformer


@dataclasses.dataclass
class ShardCtx:
    mesh: object = None
    rules: dict | None = None

    def constrain(self, x, axes):
        if self.mesh is None:
            return x
        return sharding.constrain(x, self.mesh, axes, self.rules)


NULL_CTX = ShardCtx()

AUX_LOSS_WEIGHT = 0.01


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._axes = None

    # -- params ----------------------------------------------------------------

    def _init(self, key):
        if self.cfg.is_encoder_decoder:
            return encdec.encdec_init(key, self.cfg)
        return transformer.decoder_init(key, self.cfg)

    def init(self, key):
        params, axes = self._init(key)
        self._axes = axes
        return params

    def abstract_params(self, key=None):
        """Shapes-only params (no allocation) + axes tree."""
        key = key if key is not None else jax.random.PRNGKey(0)
        box = {}

        def f(k):
            p, a = self._init(k)
            box["axes"] = a
            return p

        shapes = jax.eval_shape(f, key)
        self._axes = box["axes"]
        return shapes, box["axes"]

    def param_axes(self):
        if self._axes is None:
            self.abstract_params()
        return self._axes

    # -- training --------------------------------------------------------------

    def loss(self, params, batch, ctx: ShardCtx = NULL_CTX):
        """batch: dict with tokens/labels (+frontend embeds). Returns (loss, metrics)."""
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            enc_out = encdec.encode(params, batch["frame_embeds"], cfg, ctx)
            x = encdec.decode_train(params, batch["tokens"], enc_out, cfg, ctx)
            aux = jnp.zeros((), jnp.float32)
        else:
            fe = batch.get("vision_embeds")
            x, aux = transformer.decoder_forward(params, batch["tokens"], cfg, ctx,
                                                 frontend_embeds=fe)
        ce = transformer.decoder_loss(params, x, batch["labels"], cfg, ctx)
        loss = ce + AUX_LOSS_WEIGHT * aux
        return loss, {"ce": ce, "aux": aux}

    # -- serving ----------------------------------------------------------------

    def prefill(self, params, batch, cache_len: int, ctx: ShardCtx = NULL_CTX,
                last_pos=None):
        """Returns (per-row last-prompt-position logits (B, Vp), caches).

        ``last_pos``: (B,) index of each row's final prompt token (ragged
        right-padded prompts, continuous batching); None → S-1 for all rows.
        """
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            enc_out = encdec.encode(params, batch["frame_embeds"], cfg, ctx)
            x, caches = encdec.decode_train(params, batch["tokens"], enc_out, cfg,
                                            ctx, return_caches=True,
                                            cache_len=cache_len)
        else:
            fe = batch.get("vision_embeds")
            x, _, caches = transformer.decoder_forward(
                params, batch["tokens"], cfg, ctx, frontend_embeds=fe,
                return_caches=True, cache_len=cache_len)
        B, S, _ = x.shape
        if last_pos is None:
            x_last = x[:, -1:, :]
        else:
            x_last = x[jnp.arange(B), last_pos][:, None, :]
        logits = transformer.decoder_logits(params, x_last, cfg, ctx)[:, 0]
        return logits, caches

    def decode_step(self, params, caches, token, pos, ctx: ShardCtx = NULL_CTX):
        if self.cfg.is_encoder_decoder:
            return encdec.encdec_decode_step(params, caches, token, pos, self.cfg, ctx)
        return transformer.decoder_decode_step(params, caches, token, pos, self.cfg, ctx)

    def empty_caches(self, batch: int, cache_len: int):
        if self.cfg.is_encoder_decoder:
            return encdec.encdec_empty_caches(self.cfg, batch, cache_len)
        return transformer.decoder_empty_caches(self.cfg, batch, cache_len)

    def cache_axes(self):
        if self.cfg.is_encoder_decoder:
            return encdec.encdec_cache_axes(self.cfg)
        return transformer.cache_axes(self.cfg)

    # -- abstract inputs ---------------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every input of the step function.

        train/prefill: token batch (+ stub frontend embeddings).
        decode: one new token + per-request positions + the full KV cache.
        """
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            out = {"tokens": sds((B, S), jnp.int32),
                   "labels": sds((B, S), jnp.int32)}
            if cfg.frontend == "vision_stub":
                out["vision_embeds"] = sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                           jnp.bfloat16)
            if cfg.frontend == "audio_stub":
                out["frame_embeds"] = sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
            return out
        if shape.kind == "prefill":
            out = {"tokens": sds((B, S), jnp.int32)}
            if cfg.frontend == "vision_stub":
                out["vision_embeds"] = sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                           jnp.bfloat16)
            if cfg.frontend == "audio_stub":
                out["frame_embeds"] = sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
            return out
        if shape.kind == "decode":
            caches = jax.eval_shape(lambda: self.empty_caches(B, S))
            return {"caches": caches,
                    "token": sds((B, 1), jnp.int32),
                    "pos": sds((B,), jnp.int32)}
        raise ValueError(shape.kind)

    def input_axes(self, shape: ShapeConfig) -> dict:
        """Logical axes for input_specs (same tree structure)."""
        cfg = self.cfg
        if shape.kind in ("train", "prefill"):
            out = {"tokens": ("batch", "seq")}
            if shape.kind == "train":
                out["labels"] = ("batch", "seq")
            if cfg.frontend == "vision_stub":
                out["vision_embeds"] = ("batch", None, None)
            if cfg.frontend == "audio_stub":
                out["frame_embeds"] = ("batch", None, None)
            return out
        return {"caches": self.cache_axes(),
                "token": ("batch", None),
                "pos": ("batch",)}


def sharded_greedy(logits, ctx: ShardCtx):
    """argmax over vocab-TP logits without all-gathering them.

    Each model shard reduces its local vocab slice to (max, argmax); only the
    16 scalar pairs cross the ICI (§Perf iteration 2).  Falls back to a plain
    argmax without a mesh.
    """
    if ctx is None or ctx.mesh is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    mesh = ctx.mesh
    from jax.sharding import PartitionSpec as P
    V = logits.shape[-1]
    msize = mesh.shape["model"]
    if V % msize:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def local(l):  # l: (B, V/m) local slice
        vloc = l.shape[-1]
        m = l.max(axis=-1)
        a = l.argmax(axis=-1).astype(jnp.int32)
        a = a + jax.lax.axis_index("model").astype(jnp.int32) * vloc
        gm = jax.lax.pmax(m, "model")
        cand = jnp.where(m >= gm, a, jnp.int32(2**30))
        return jax.lax.pmin(cand, "model")  # lowest index among ties

    fn = shard_map(local, mesh=mesh,
                       in_specs=P(None, "model"), out_specs=P(),
                       check_vma=False)
    return fn(logits)


def build_model(name_or_cfg, smoke: bool = False) -> Model:
    if isinstance(name_or_cfg, ModelConfig):
        return Model(name_or_cfg)
    from repro.configs import get_config
    return Model(get_config(name_or_cfg, smoke=smoke))
