"""Decoder-only transformer supporting dense / MoE / SSM / hybrid layer stacks.

The layer pattern (which mixer, which FFN per layer) is folded into the
smallest repeating *period* P; layers are stacked into P parallel stacks of
``n_layers / P`` super-blocks and executed with one ``lax.scan`` over
super-blocks (compact HLO, O(1) compile cost in depth) with optional remat.
Homogeneous models have P = 1; Jamba has P = 8 (7 Mamba + 1 attention,
MoE on odd layers).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .layers import (attention_apply, attention_decode, attention_init,
                     embed_init, embed_lookup, mlp_apply, mlp_init, pdtype,
                     rmsnorm, rmsnorm_init)
from .moe import moe_apply, moe_apply_dense, moe_init
from .ssm import ssm_apply, ssm_decode, ssm_init


def pattern_period(cfg) -> int:
    kinds = [(cfg.layer_kind(i), cfg.ffn_kind(i)) for i in range(cfg.n_layers)]
    for p in range(1, cfg.n_layers + 1):
        if cfg.n_layers % p:
            continue
        if all(kinds[i] == kinds[i % p] for i in range(cfg.n_layers)):
            return p
    return cfg.n_layers


def block_init(key, cfg, idx_in_period: int):
    """One (mixer + ffn) block."""
    mixer_kind = cfg.layer_kind(idx_in_period)
    ffn_kind = cfg.ffn_kind(idx_in_period)
    ks = jax.random.split(key, 4)
    params, axes = {}, {}
    params["norm1"], axes["norm1"] = rmsnorm_init(cfg)
    if mixer_kind == "attn":
        params["attn"], axes["attn"] = attention_init(ks[0], cfg)
    else:
        params["ssm"], axes["ssm"] = ssm_init(ks[0], cfg)
    if ffn_kind != "none":
        params["norm2"], axes["norm2"] = rmsnorm_init(cfg)
        if ffn_kind == "moe":
            params["moe"], axes["moe"] = moe_init(ks[1], cfg)
        else:
            params["mlp"], axes["mlp"] = mlp_init(ks[1], cfg)
    return params, axes


def block_apply(p, x, cfg, ctx, positions):
    """Full-sequence block (train/prefill). Returns (x, cache, aux)."""
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if ctx is not None:
        h = ctx.constrain(h, ("batch", "act_seq", None))
    cache = {}
    if "attn" in p:
        out, (k, v) = attention_apply(p["attn"], h, cfg, ctx, positions)
        cache = {"k": k, "v": v}
    else:
        out, (conv_states, h_final) = ssm_apply(p["ssm"], h, cfg, ctx,
                                                return_state=True)
        cache = {"conv": conv_states, "state": h_final}
    x = x + out
    if ctx is not None:
        x = ctx.constrain(x, ("batch", "act_seq", None))
    aux = jnp.zeros((), jnp.float32)
    if "norm2" in p:
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if "moe" in p:
            ff, aux = moe_apply(p["moe"], h2, cfg, ctx)
        else:
            ff = mlp_apply(p["mlp"], h2)
        x = x + ff
        if ctx is not None:
            x = ctx.constrain(x, ("batch", "act_seq", None))
    return x, cache, aux


def block_decode(p, cache, x, cfg, ctx, pos):
    """Single-token block. Returns (x, new_cache)."""
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if "attn" in p:
        out, ck, cv = attention_decode(p["attn"], h, cfg, ctx,
                                       cache["k"], cache["v"], pos)
        new_cache = {"k": ck, "v": cv}
    else:
        out, conv_states, state = ssm_decode(p["ssm"], h, cfg, ctx,
                                             cache["conv"], cache["state"])
        new_cache = {"conv": conv_states, "state": state}
    x = x + out
    if "norm2" in p:
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        ff = (moe_apply_dense(p["moe"], h2, cfg, ctx)[0] if "moe" in p
              else mlp_apply(p["mlp"], h2))
        x = x + ff
    return x, new_cache


# -- model-level init ----------------------------------------------------------

def decoder_init(key, cfg):
    P = pattern_period(cfg)
    nb = cfg.n_layers // P
    ks = jax.random.split(key, P + 3)
    params, axes = {}, {}
    params["embed"], axes["embed"] = embed_init(ks[0], cfg)
    if not cfg.use_rope:
        tbl, ax = jnp.zeros((cfg.max_seq_len, cfg.d_model), pdtype(cfg)), (None, "embed")
        params["pos_embed"], axes["pos_embed"] = tbl, ax
    if not cfg.tie_embeddings:
        from .layers import dense_init
        params["out_head"], axes["out_head"] = dense_init(
            ks[1], (cfg.d_model, cfg.vocab_padded), ("embed", "vocab"), dtype=pdtype(cfg))
    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg)
    blocks_p, blocks_a = {}, {}
    for j in range(P):
        keys = jax.random.split(ks[2 + j], nb)
        stacked = jax.vmap(lambda k, j=j: block_init(k, cfg, j)[0])(keys)
        _, a = block_init(ks[2 + j], cfg, j)
        blocks_p[f"sub{j}"] = stacked
        blocks_a[f"sub{j}"] = jax.tree.map(
            lambda t: ("layers",) + t, a, is_leaf=lambda t: isinstance(t, tuple))
    params["blocks"] = blocks_p
    axes["blocks"] = blocks_a
    return params, axes


# -- full-sequence forward ------------------------------------------------------

def decoder_forward(params, tokens, cfg, ctx, frontend_embeds=None,
                    return_caches: bool = False, cache_len: int | None = None):
    """tokens: (B, S) int32 → final hidden (B, S, D) [+ caches, aux_loss].

    ``frontend_embeds``: (B, n_frontend_tokens, D) stub modality embeddings
    overwriting the leading positions (VLM).
    ``return_caches``: prefill mode — also return decode caches padded to
    ``cache_len``.
    """
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens)
    if frontend_embeds is not None:
        nf = min(frontend_embeds.shape[1], S)
        x = jax.lax.dynamic_update_slice(
            x, frontend_embeds[:, :nf].astype(x.dtype), (0, 0, 0))
    if not cfg.use_rope:
        x = x + params["pos_embed"][None, :S, :]
    if ctx is not None:
        x = ctx.constrain(x, ("batch", "act_seq", None))
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    P = pattern_period(cfg)

    def superblock(x, block_params):
        aux_total = jnp.zeros((), jnp.float32)
        caches = {}
        for j in range(P):
            x, cache, aux = block_apply(block_params[f"sub{j}"], x, cfg, ctx,
                                        positions)
            if return_caches:
                caches[f"sub{j}"] = cache
            aux_total = aux_total + aux
        return x, (caches, aux_total)

    if cfg.remat:
        if cfg.remat_policy == "dots":
            # §Perf: save matmul outputs — trades remat recompute FLOPs
            # (~1/4 of the step) for activation memory
            sb = jax.checkpoint(
                superblock,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            sb = jax.checkpoint(superblock)
    else:
        sb = superblock
    x, (caches, auxes) = jax.lax.scan(sb, x, params["blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    aux_loss = auxes.sum()
    if not return_caches:
        return x, aux_loss

    # Prefill: pad attention k/v to cache_len; ssm caches are final states.
    cache_len = cache_len or S

    def pad_cache(c):
        out = {}
        for name, sub in c.items():
            if "k" in sub:  # attention: (nb, B, S, Hkv, hd) → (nb, B, cache_len, ...)
                k, v = sub["k"], sub["v"]
                pad = [(0, 0), (0, 0), (0, cache_len - k.shape[2]), (0, 0), (0, 0)]
                out[name] = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
            else:
                out[name] = sub
        return out

    return x, aux_loss, pad_cache(caches)


def decoder_logits(params, x, cfg, ctx):
    """Final hidden → (B,S,Vp) f32 logits with pad vocab masked to -1e30.

    Only for small S (decode steps / tests); training uses ``decoder_loss``,
    which never materializes the full logits tensor.
    """
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["out_head"])
    logits = (x @ head).astype(jnp.float32)
    if cfg.vocab_padded > cfg.vocab_size:
        v_idx = jnp.arange(cfg.vocab_padded)
        logits = jnp.where(v_idx < cfg.vocab_size, logits, -1e30)
    return logits


def decoder_loss(params, x, labels, cfg, ctx, chunk: int = 512):
    """Chunked cross-entropy over the sequence. x: (B,S,D), labels: (B,S)."""
    B, S, D = x.shape
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["out_head"])
    c = min(chunk, S)
    while S % c:
        c -= 1
    xr = x.reshape(B, S // c, c, D).transpose(1, 0, 2, 3)
    lr = labels.reshape(B, S // c, c).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(carry, inp):
        # checkpointed: backward recomputes the chunk logits instead of
        # saving (B, c, Vp) f32 per chunk across the whole scan.
        xc, lc = inp                                   # (B,c,D), (B,c)
        logits = (xc @ head).astype(jnp.float32)       # (B,c,Vp)
        if ctx is not None:
            logits = ctx.constrain(logits, ("batch", None, "vocab"))
        if cfg.vocab_padded > cfg.vocab_size:
            v_idx = jnp.arange(cfg.vocab_padded)
            logits = jnp.where(v_idx[None, None, :] < cfg.vocab_size,
                               logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + (lse - gold).sum(), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (xr, lr))
    return total / (B * S)


# -- decode ---------------------------------------------------------------------

def decoder_decode_step(params, caches, token, pos, cfg, ctx):
    """token: (B,1) int32; pos: (B,) int32; caches from prefill/empty_caches.

    Returns (logits (B, vocab_padded), new_caches).
    """
    B = token.shape[0]
    x = embed_lookup(params["embed"], token)
    if not cfg.use_rope:
        x = x + params["pos_embed"][pos][:, None, :]
    if ctx is not None:
        x = ctx.constrain(x, ("batch", None, None))
    P = pattern_period(cfg)

    def scan_body(x, inp):
        block_params, cache = inp
        new_caches = {}
        for j in range(P):
            x, nc = block_decode(block_params[f"sub{j}"], cache[f"sub{j}"],
                                 x, cfg, ctx, pos)
            new_caches[f"sub{j}"] = nc
        return x, new_caches

    x, new_caches = jax.lax.scan(scan_body, x, (params["blocks"], caches))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = decoder_logits(params, x, cfg, ctx)[:, 0, :]
    return logits, new_caches


def decoder_empty_caches(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """Abstract-friendly empty cache tree matching decoder_decode_step."""
    from .ssm import ssm_dims
    P = pattern_period(cfg)
    nb = cfg.n_layers // P
    hd = cfg.resolved_head_dim
    caches = {}
    for j in range(P):
        if cfg.layer_kind(j) == "attn":
            caches[f"sub{j}"] = {
                "k": jnp.zeros((nb, batch, cache_len, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((nb, batch, cache_len, cfg.n_kv_heads, hd), dtype),
            }
        else:
            d_inner, H, Pd, N = ssm_dims(cfg)
            K = cfg.ssm_conv
            caches[f"sub{j}"] = {
                "conv": {"x": jnp.zeros((nb, batch, K - 1, d_inner), dtype),
                         "B": jnp.zeros((nb, batch, K - 1, N), dtype),
                         "C": jnp.zeros((nb, batch, K - 1, N), dtype)},
                "state": jnp.zeros((nb, batch, H, Pd, N), jnp.float32),
            }
    return caches


def cache_axes(cfg):
    """Logical axes tree for decode caches (mirrors decoder_empty_caches)."""
    P = pattern_period(cfg)
    axes = {}
    for j in range(P):
        if cfg.layer_kind(j) == "attn":
            axes[f"sub{j}"] = {
                "k": ("layers", "cache_batch", "kv_seq", "kv_heads", "head_dim"),
                "v": ("layers", "cache_batch", "kv_seq", "kv_heads", "head_dim"),
            }
        else:
            axes[f"sub{j}"] = {
                "conv": {"x": ("layers", "cache_batch", "conv", "mlp"),
                         "B": ("layers", "cache_batch", "conv", "ssm_state"),
                         "C": ("layers", "cache_batch", "conv", "ssm_state")},
                "state": ("layers", "cache_batch", "ssm_heads", None, "ssm_state"),
            }
    return axes
