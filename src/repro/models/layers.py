"""Foundational model layers: init helpers, RMSNorm, RoPE, GQA attention
(chunked flash-style for train/prefill, cache-based for decode), SwiGLU MLP.

Conventions
-----------
* Every ``*_init(key, cfg)`` returns ``(params, axes)`` — two trees of the
  same structure; ``axes`` leaves are tuples of logical axis names consumed by
  :mod:`repro.sharding`.
* Params are stored in ``cfg.dtype`` (bf16 by default); norms, softmax and
  attention accumulation run in f32.
* ``ctx`` is a ShardCtx (see model.py) used to place sharding constraints on
  key activations; it is a no-op in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pdtype(cfg):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, axes, scale: float | None = None, dtype=jnp.bfloat16):
    """Normal(0, scale) init; default scale = 1/sqrt(fan_in)."""
    if scale is None:
        scale = 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype), axes


# -- norm ---------------------------------------------------------------------

def rmsnorm_init(cfg, d=None):
    d = d or cfg.d_model
    return {"scale": jnp.ones((d,), pdtype(cfg))}, {"scale": (None,)}


def rmsnorm(p, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def head_rmsnorm(scale, x, eps: float):
    """Per-head qk-norm over the head_dim axis."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# -- rotary -------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- attention ------------------------------------------------------------------

def head_mask(cfg) -> jnp.ndarray:
    """(padded_heads,) f32 mask: 1 for real q heads, 0 for group padding.

    Padded q-head layout is (kv_head, group) flattened, so real heads are the
    first ``group_size`` of each ``padded_group_size`` group — GQA head→kv
    mapping is preserved exactly for real heads.
    """
    g = jnp.arange(cfg.padded_heads) % cfg.padded_group_size
    return (g < cfg.group_size).astype(jnp.float32)


def attention_init(key, cfg, cross: bool = False):
    d, hkv = cfg.d_model, cfg.n_kv_heads
    hq = cfg.padded_heads
    hd = cfg.resolved_head_dim
    dt = pdtype(cfg)
    ks = jax.random.split(key, 4)
    params, axes = {}, {}
    params["wq"], axes["wq"] = dense_init(ks[0], (d, hq, hd), ("embed", "q_heads", "head_dim"), dtype=dt)
    params["wk"], axes["wk"] = dense_init(ks[1], (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dtype=dt)
    params["wv"], axes["wv"] = dense_init(ks[2], (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dtype=dt)
    params["wo"], axes["wo"] = dense_init(ks[3], (hq, hd, d), ("q_heads", "head_dim", "embed"),
                                          scale=1.0 / np.sqrt(hq * hd), dtype=dt)
    if cfg.qk_norm and not cross:
        params["q_norm"] = jnp.ones((hd,), dt)
        params["k_norm"] = jnp.ones((hd,), dt)
        axes["q_norm"] = ("head_dim",)
        axes["k_norm"] = ("head_dim",)
    return params, axes


def _qkv(p, x, kv_x, cfg, positions, kv_positions, rope: bool):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", kv_x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_x, p["wv"])
    if cfg.qk_norm and "q_norm" in p:
        q = head_rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = head_rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope and cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    if cfg.padded_heads != cfg.n_heads:
        q = q * head_mask(cfg)[None, None, :, None].astype(q.dtype)
    return q, k, v


def chunked_attention(q, k, v, n_kv_heads: int, causal: bool,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      q_offset=0, ctx=None) -> jax.Array:
    """Flash-style streaming-softmax attention in pure jnp.

    q: (B, S, Hq, hd); k, v: (B, T, Hkv, hd).  Memory is O(q_chunk × kv_chunk)
    per step instead of O(S × T); the double lax.scan keeps the HLO compact for
    very long sequences.  Causal masking uses absolute positions
    (q position = q_offset + index), so prefill-with-history works.

    Each q-chunk is wrapped in ``jax.checkpoint``: the backward pass re-streams
    the KV scan per chunk instead of saving every (qc × kc) probability tile —
    the flash-attention memory property, expressed at the JAX level.
    """
    B, S, Hq, hd = q.shape
    T = k.shape[1]
    G = Hq // n_kv_heads
    scale = 1.0 / np.sqrt(hd)

    # GQA: broadcast KV to flat q-heads.  Keeping the head axis FLAT (no
    # (Hkv, G) reshape) is what lets GSPMD keep heads sharded on the model
    # axis — a (48,)→(8,6) reshape of a 16-way-sharded axis forces
    # replication.  The repeated KV is sharded like q, so the per-device
    # footprint is (T × Hq/shards × hd), not ×G of the original.
    head_to_kv = jnp.arange(Hq) // G
    k = jnp.take(k, head_to_kv, axis=2)   # (B, T, Hq, hd)
    v = jnp.take(v, head_to_kv, axis=2)
    if ctx is not None:
        # Megatron-SP boundary: residuals are sequence-sharded on `model`;
        # attention itself is head-sharded.  These constraints make GSPMD
        # all-gather the sequence HERE and shard heads, instead of running
        # the whole attention replicated.
        hax = ("batch", None, "q_heads", None)
        q = ctx.constrain(q, hax)
        k = ctx.constrain(k, hax)
        v = ctx.constrain(v, hax)

    qc = min(q_chunk, S)
    while S % qc:
        qc -= 1
    kc = min(kv_chunk, T)
    while T % kc:
        kc -= 1

    qr = q.reshape(B, S // qc, qc, Hq, hd)
    kr = k.reshape(B, T // kc, kc, Hq, hd)
    vr = v.reshape(B, T // kc, kc, Hq, hd)

    q_pos = q_offset + jnp.arange(S).reshape(S // qc, qc)
    k_pos = jnp.arange(T).reshape(T // kc, kc)

    def per_q_chunk(args):
        qck, qp = args  # (B, qc, Hq, hd), (qc,)

        def kv_step(carry, inp):
            m, l, acc = carry
            kck, vck, kp = inp  # (B, kc, Hq, hd), (B, kc, Hq, hd), (kc,)
            s = jnp.einsum("bqhd,bkhd->bhqk", qck, kck).astype(jnp.float32) * scale
            if causal:
                mask = qp[:, None] >= kp[None, :]  # (qc, kc)
                s = jnp.where(mask[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard: fully-masked rows have m == -inf
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p_ = jnp.exp(s - m_safe[..., None])
            if causal:
                p_ = jnp.where(mask[None, None], p_, 0.0)
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            corr = jnp.where(jnp.isfinite(corr), corr, 0.0)
            l_new = l * corr + p_.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p_, vck.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hq, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hq, qc), jnp.float32)
        a0 = jnp.zeros((B, Hq, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4), k_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B, Hq, qc, hd)
        return out.transpose(0, 2, 1, 3)               # (B, qc, Hq, hd)

    outs = jax.lax.map(jax.checkpoint(per_q_chunk),
                       (qr.transpose(1, 0, 2, 3, 4), q_pos))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, Hq, hd)
    out = out.astype(q.dtype)
    if ctx is not None:
        out = ctx.constrain(out, ("batch", None, "q_heads", None))
    return out


def attention_apply(p, x, cfg, ctx, positions, causal: bool = True,
                    kv_x=None, kv_positions=None, rope: bool = True):
    """Full-sequence attention (train / prefill / encoder / cross).

    Returns (out (B,S,D), (k, v)) — k/v returned for cache construction.
    """
    kv_x = x if kv_x is None else kv_x
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _qkv(p, x, kv_x, cfg, positions, kv_positions, rope=rope)
    o = chunked_attention(q, k, v, cfg.n_kv_heads, causal=causal,
                          q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
                          ctx=ctx)
    if cfg.padded_heads != cfg.n_heads:
        o = o * head_mask(cfg)[None, None, :, None].astype(o.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, (k, v)


def attention_decode(p, x, cfg, ctx, cache_k, cache_v, pos):
    """Single-token decode. x: (B, 1, D); cache_{k,v}: (B, Smax, Hkv, hd);
    pos: (B,) int32 — per-request current position (continuous batching).

    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    positions = pos[:, None]
    q, k, v = _qkv(p, x, x, cfg, positions, positions, rope=True)
    b_idx = jnp.arange(B)
    cache_k = cache_k.at[b_idx, pos].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[b_idx, pos].set(v[:, 0].astype(cache_v.dtype))
    if ctx is not None:
        cache_k = ctx.constrain(cache_k, ("cache_batch", "kv_seq", "kv_heads", "head_dim"))
        cache_v = ctx.constrain(cache_v, ("cache_batch", "kv_seq", "kv_heads", "head_dim"))
    Hq = cfg.padded_heads
    Hkv = cfg.n_kv_heads
    G = Hq // Hkv
    hd = q.shape[-1]
    # flat-head GQA (see chunked_attention): broadcast cached KV to q heads
    head_to_kv = jnp.arange(Hq) // G
    ck = jnp.take(cache_k, head_to_kv, axis=2)                # (B, T, Hq, hd)
    cv = jnp.take(cache_v, head_to_kv, axis=2)
    if ctx is not None:
        hax = ("cache_batch", "kv_seq", "q_heads", None)
        ck = ctx.constrain(ck, hax)
        cv = ctx.constrain(cv, hax)
    qf = q[:, 0]                                              # (B, Hq, hd)
    if ctx is not None:
        qf = ctx.constrain(qf, ("cache_batch", "q_heads", None))
    s = jnp.einsum("bhd,bthd->bht", qf, ck).astype(jnp.float32) / np.sqrt(hd)
    t_idx = jnp.arange(cache_k.shape[1])
    valid = t_idx[None, :] <= pos[:, None]                    # (B, T)
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bht,bthd->bhd", w, cv.astype(jnp.float32))
    o = o.reshape(B, 1, Hq, hd).astype(x.dtype)
    if Hq != cfg.n_heads:
        o = o * head_mask(cfg)[None, None, :, None].astype(o.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, cache_k, cache_v


# -- MLP -----------------------------------------------------------------------

def mlp_init(key, cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = pdtype(cfg)
    ks = jax.random.split(key, 3)
    params, axes = {}, {}
    params["w_gate"], axes["w_gate"] = dense_init(ks[0], (d, f), ("embed", "mlp"), dtype=dt)
    params["w_up"], axes["w_up"] = dense_init(ks[1], (d, f), ("embed", "mlp"), dtype=dt)
    params["w_down"], axes["w_down"] = dense_init(ks[2], (f, d), ("mlp", "embed"), dtype=dt)
    return params, axes


def mlp_apply(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


# -- embedding -------------------------------------------------------------------

def embed_init(key, cfg):
    dt = pdtype(cfg)
    tbl, ax = dense_init(key, (cfg.vocab_padded, cfg.d_model), ("vocab", "embed"),
                         scale=0.02, dtype=dt)
    return {"table": tbl}, {"table": ax}


def embed_lookup(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)
