"""Model zoo: GQA transformer, MoE, Mamba2/SSD, hybrid, enc-dec."""

from .model import Model, ShardCtx, NULL_CTX, build_model

__all__ = ["Model", "ShardCtx", "NULL_CTX", "build_model"]
