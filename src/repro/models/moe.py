"""Mixture-of-Experts FFN with sort-based (dropping) dispatch.

Design: tokens are routed top-k, flattened to (T·k) assignments, sorted by
expert id, ranked within each expert's run, and scattered into a dense
``(E, C, D)`` buffer (C = capacity).  Expert FFNs run as batched einsums over
the expert axis; results are gathered back with routing weights.  Assignments
beyond capacity are dropped (standard capacity-factor semantics).

Expert parallelism: the (E, C, D) buffer and all expert weights carry the
``experts`` logical axis → the `model` mesh axis; GSPMD turns the scatter /
gather into all-to-alls across the model axis.  Experts are padded to a
multiple of 16 (``cfg.experts_padded``) with −inf router logits so padded
experts never receive tokens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import numpy as np

from repro.compat import shard_map

from .layers import dense_init, pdtype


def moe_init(key, cfg):
    d, f = cfg.d_model, cfg.moe_d_ff
    e = cfg.experts_padded
    dt = pdtype(cfg)
    ks = jax.random.split(key, 4)
    params, axes = {}, {}
    # router is replicated: every device routes its local tokens over all experts
    params["router"], axes["router"] = dense_init(
        ks[0], (d, e), (None, None), dtype=jnp.float32)
    params["w_gate"], axes["w_gate"] = dense_init(
        ks[1], (e, d, f), ("experts", "embed", "expert_mlp"),
        scale=1.0 / np.sqrt(d), dtype=dt)
    params["w_up"], axes["w_up"] = dense_init(
        ks[2], (e, d, f), ("experts", "embed", "expert_mlp"),
        scale=1.0 / np.sqrt(d), dtype=dt)
    params["w_down"], axes["w_down"] = dense_init(
        ks[3], (e, f, d), ("experts", "expert_mlp", "embed"),
        scale=1.0 / np.sqrt(f), dtype=dt)
    return params, axes


def moe_apply_dense(p, x, cfg, ctx):
    """No-drop MoE for decode steps: every expert runs on every token, outputs
    are combined with (renormalized) top-k gates.  Exact (capacity-free)
    routing semantics; compute is E/k× the routed path, which is the right
    trade at decode batch sizes — it avoids the dispatch all-to-alls entirely
    and keeps decode causal/deterministic.

    x: (B, S, D) with small B·S. Returns (out, aux=0).
    """
    B, S, D = x.shape
    T = B * S
    E = cfg.experts_padded
    k = cfg.top_k
    xf = x.reshape(T, D)
    logits = xf.astype(jnp.float32) @ p["router"]
    if E > cfg.n_experts:
        logits = jnp.where(jnp.arange(E)[None, :] >= cfg.n_experts, -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    gates = jnp.zeros((T, E), jnp.float32).at[
        jnp.repeat(jnp.arange(T), k), idx.reshape(-1)].add(w.reshape(-1))

    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["w_gate"]))
    h = h * jnp.einsum("td,edf->tef", xf, p["w_up"])
    y_all = jnp.einsum("tef,efd->ted", h, p["w_down"])     # (T, E, D)
    y = jnp.einsum("ted,te->td", y_all.astype(jnp.float32), gates)
    return y.astype(x.dtype).reshape(B, S, D), jnp.zeros((), jnp.float32)


def moe_apply(p, x, cfg, ctx):
    """Routed MoE FFN. x: (B, S, D) → (out (B, S, D), aux_loss scalar f32).

    With a mesh in ctx (and divisible shapes) this uses the expert-parallel
    shard_map path (explicit all-to-alls); otherwise the single-device global
    formulation.
    """
    if ctx is not None and getattr(ctx, "mesh", None) is not None:
        mesh = ctx.mesh
        B, S, D = x.shape
        data_ax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        dsize = 1
        for a in data_ax:
            dsize *= mesh.shape[a]
        msize = mesh.shape["model"]
        if (B % dsize == 0 and S % msize == 0
                and cfg.experts_padded % msize == 0):
            return _moe_apply_ep(p, x, cfg, ctx, data_ax, msize)
    return _moe_apply_global(p, x, cfg, ctx)


def _moe_apply_ep(p, x, cfg, ctx, data_ax, msize):
    """Expert-parallel dispatch inside shard_map (GShard-style).

    Tokens are sharded (batch → data axes, sequence → model axis); each device
    routes its local tokens, builds a per-(device, expert) capacity buffer,
    exchanges it with two ``all_to_all``s over the model axis around the
    expert FFN, and combines locally.  Capacity is per source device —
    standard EP semantics.
    """
    import numpy as np
    mesh = ctx.mesh
    E = cfg.experts_padded
    k = cfg.top_k
    from jax.sharding import PartitionSpec as P

    x_spec = P(data_ax, "model", None)
    w_spec = P("model", None, None)
    all_axes = tuple(mesh.axis_names)

    def local(xl, router, w_gate, w_up, w_down):
        Bl, Sl, D = xl.shape
        T = Bl * Sl
        xf = xl.reshape(T, D)
        logits = xf.astype(jnp.float32) @ router
        if E > cfg.n_experts:
            logits = jnp.where(jnp.arange(E)[None, :] >= cfg.n_experts,
                               -jnp.inf, logits)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
        aux_local = (me * ce).sum() * cfg.n_experts
        aux = jax.lax.pmean(aux_local, all_axes)

        fe = idx.reshape(-1)
        fw = w.reshape(-1)
        ftok = jnp.repeat(jnp.arange(T), k)
        order = jnp.argsort(fe, stable=True)
        fe_s, fw_s, ftok_s = fe[order], fw[order], ftok[order]
        seg_start = jnp.searchsorted(fe_s, jnp.arange(E))
        rank = jnp.arange(T * k) - seg_start[fe_s]
        cap = int(np.ceil(cfg.capacity_factor * T * k / E))
        cap = max(4, ((cap + 3) // 4) * 4)
        keep = rank < cap
        rank_c = jnp.where(keep, rank, 0)

        buf = jnp.zeros((E, cap, D), xl.dtype)
        buf = buf.at[fe_s, rank_c].add(jnp.where(keep[:, None], xf[ftok_s], 0))

        # route to expert owners: (E, cap, D) -> (E/m, m·cap, D)
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
        h = h * jnp.einsum("ecd,edf->ecf", buf, w_up)
        out = jnp.einsum("ecf,efd->ecd", h, w_down)
        # route back: (E/m, m·cap, D) -> (E, cap, D)
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                 tiled=True)

        gathered = out[fe_s, rank_c]
        contrib = gathered * (fw_s * keep).astype(gathered.dtype)[:, None]
        y = jnp.zeros((T, D), xl.dtype).at[ftok_s].add(contrib)
        return y.reshape(Bl, Sl, D), aux

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
        check_vma=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _moe_apply_global(p, x, cfg, ctx):
    """Single-device / no-mesh fallback (same math, global capacity)."""
    B, S, D = x.shape
    T = B * S
    E = cfg.experts_padded
    k = cfg.top_k
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"])  # (T, E) f32
    if E > cfg.n_experts:  # mask padded experts
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)                 # (T, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)                                   # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    aux = (me * ce).sum() * (cfg.n_experts ** 2) / cfg.n_experts

    # flatten assignments and rank within expert
    fe = idx.reshape(-1)                                       # (T*k,)
    fw = w.reshape(-1)
    ftok = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(fe, stable=True)
    fe_s, fw_s, ftok_s = fe[order], fw[order], ftok[order]
    seg_start = jnp.searchsorted(fe_s, jnp.arange(E))          # (E,)
    rank = jnp.arange(T * k) - seg_start[fe_s]

    cap = int(np.ceil(cfg.capacity_factor * T * k / E))
    cap = max(4, ((cap + 3) // 4) * 4)
    keep = rank < cap
    rank_c = jnp.where(keep, rank, 0)

    buf = jnp.zeros((E, cap, D), x.dtype)
    vals = jnp.where(keep[:, None], xf[ftok_s], 0)
    buf = buf.at[fe_s, rank_c].add(vals)
    if ctx is not None:
        buf = ctx.constrain(buf, ("experts", None, None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    if ctx is not None:
        out_buf = ctx.constrain(out_buf, ("experts", None, None))

    gathered = out_buf[fe_s, rank_c]                           # (T*k, D)
    contrib = gathered * (fw_s * keep).astype(gathered.dtype)[:, None]
    y = jnp.zeros((T, D), x.dtype).at[ftok_s].add(contrib)
    return y.reshape(B, S, D), aux
