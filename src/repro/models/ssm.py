"""Mamba-2 mixer via SSD (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
computation *within* chunks (MXU-friendly batched matmuls) plus a linear
inter-chunk state scan.  Decode is the O(1)-state recurrence.  A naive
step-by-step recurrence (``ssd_reference``) is kept as the test oracle.

Shapes (per block): d_inner = expand·d_model; P = ssm_head_dim;
H = d_inner / P heads; N = ssm_state.  n_groups = 1 (B/C shared across heads).

All state-decay exponentials are of non-positive arguments (A < 0, dt > 0),
so the chunked form is overflow-safe by construction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, pdtype, rmsnorm


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_inner // P
    N = cfg.ssm_state
    return d_inner, H, P, N


def ssm_init(key, cfg):
    d = cfg.d_model
    d_inner, H, P, N = ssm_dims(cfg)
    K = cfg.ssm_conv
    dt = pdtype(cfg)
    ks = jax.random.split(key, 10)
    params, axes = {}, {}
    params["w_z"], axes["w_z"] = dense_init(ks[0], (d, d_inner), ("embed", "mlp"), dtype=dt)
    params["w_x"], axes["w_x"] = dense_init(ks[1], (d, d_inner), ("embed", "mlp"), dtype=dt)
    params["w_B"], axes["w_B"] = dense_init(ks[2], (d, N), ("embed", "ssm_state"), dtype=dt)
    params["w_C"], axes["w_C"] = dense_init(ks[3], (d, N), ("embed", "ssm_state"), dtype=dt)
    params["w_dt"], axes["w_dt"] = dense_init(ks[4], (d, H), ("embed", "ssm_heads"), dtype=dt)
    # dt bias: softplus(dt_bias) ∈ [1e-3, 1e-1]
    u = jax.random.uniform(ks[5], (H,), jnp.float32,
                           np.log(1e-3), np.log(1e-1))
    params["dt_bias"] = jnp.log(jnp.expm1(jnp.exp(u)))
    axes["dt_bias"] = ("ssm_heads",)
    params["A_log"] = jnp.log(jax.random.uniform(ks[6], (H,), jnp.float32, 1.0, 16.0))
    axes["A_log"] = ("ssm_heads",)
    params["D_skip"] = jnp.ones((H,), jnp.float32)
    axes["D_skip"] = ("ssm_heads",)
    params["conv_x"], axes["conv_x"] = dense_init(
        ks[7], (K, d_inner), ("conv", "mlp"), scale=1.0 / np.sqrt(K), dtype=dt)
    params["conv_B"], axes["conv_B"] = dense_init(
        ks[8], (K, N), ("conv", "ssm_state"), scale=1.0 / np.sqrt(K), dtype=dt)
    params["conv_C"], axes["conv_C"] = dense_init(
        ks[9], (K, N), ("conv", "ssm_state"), scale=1.0 / np.sqrt(K), dtype=dt)
    params["out_norm"] = jnp.ones((d_inner,), dt)
    axes["out_norm"] = (None,)
    params["w_out"], axes["w_out"] = dense_init(
        jax.random.fold_in(key, 99), (d_inner, d), ("mlp", "embed"), dtype=dt)
    return params, axes


def _causal_conv(u, w):
    """Depthwise causal conv. u: (B, S, C); w: (K, C) → (B, S, C)."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, k:k + u.shape[1], :] * w[k][None, None, :] for k in range(K))
    return out


def _conv_step(u_t, conv_state, w):
    """Single-step conv. u_t: (B, C); conv_state: (B, K-1, C) (oldest first)."""
    window = jnp.concatenate([conv_state, u_t[:, None, :]], axis=1)  # (B, K, C)
    y = (window * w[None]).sum(axis=1)
    new_state = window[:, 1:, :]
    return y, new_state


def ssd_chunked(x, dt, A, Bm, Cm, D_skip, chunk: int, h0=None):
    """Chunked SSD: ``lax.scan`` over chunks carrying the inter-chunk state.

    x: (B,S,H,P) f32; dt: (B,S,H) f32; A: (H,) f32 (negative);
    Bm, Cm: (B,S,N) f32; D_skip: (H,).
    Returns (y (B,S,H,P), h_final (B,H,P,N)).

    The intra-chunk quadratic work materializes only one chunk's (L, L, H)
    decay tensor at a time, and the chunk body is checkpointed so backward
    re-materializes per chunk instead of saving every chunk's tensors.
    All decay exponents are ≤ 0 (A < 0, dt > 0) → overflow-safe.
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    while S % L:
        L -= 1
    nc = S // L
    xr = x.reshape(Bsz, nc, L, H, P).transpose(1, 0, 2, 3, 4)
    dtr = dt.reshape(Bsz, nc, L, H).transpose(1, 0, 2, 3)
    Br = Bm.reshape(Bsz, nc, L, N).transpose(1, 0, 2, 3)
    Cr = Cm.reshape(Bsz, nc, L, N).transpose(1, 0, 2, 3)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    mask = jnp.tril(jnp.ones((L, L), bool))

    @jax.checkpoint
    def chunk_step(h_prev, inp):
        xc, dtc, Bc, Cc = inp          # (B,L,H,P), (B,L,H), (B,L,N), (B,L,N)
        a = dtc * A[None, None, :]                   # (B,L,H) ≤ 0
        cum = jnp.cumsum(a, axis=1)                  # inclusive
        total = cum[:, -1, :]                        # (B,H)
        # intra-chunk
        scores = jnp.einsum("bin,bjn->bij", Cc, Bc)  # (B,L,L)
        seg = cum[:, :, None, :] - cum[:, None, :, :]      # (B,i,j,H)
        decay = jnp.where(mask[None, :, :, None], jnp.exp(seg), 0.0)
        M = scores[..., None] * decay * dtc[:, None, :, :]  # (B,i,j,H)
        y = jnp.einsum("bijh,bjhp->bihp", M, xc)
        # contribution of the carried state
        y = y + jnp.einsum("blh,bln,bhpn->blhp", jnp.exp(cum), Cc, h_prev)
        y = y + D_skip[None, None, :, None] * xc
        # state update
        w_state = jnp.exp(total[:, None, :] - cum) * dtc    # (B,L,H)
        S_c = jnp.einsum("blh,blhp,bln->bhpn", w_state, xc, Bc)
        h_new = jnp.exp(total)[:, :, None, None] * h_prev + S_c
        return h_new, y

    h_final, ys = jax.lax.scan(chunk_step, h0, (xr, dtr, Br, Cr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y, h_final


def ssd_reference(x, dt, A, Bm, Cm, D_skip):
    """Naive per-step recurrence (test oracle). Same shapes as ssd_chunked."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dt_t * A[None, :])[:, :, None, None]
        inject = dt_t[:, :, None, None] * x_t[..., None] * B_t[:, None, None, :]
        h = decay * h + inject
        y_t = jnp.einsum("bhpn,bn->bhp", h, C_t) + D_skip[None, :, None] * x_t
        return h, y_t

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
                                    Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3)


def _project(p, x, cfg):
    """Shared projections for train/prefill. x: (B,S,D)."""
    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    Bm = x @ p["w_B"]
    Cm = x @ p["w_C"]
    dt_raw = (x @ p["w_dt"]).astype(jnp.float32)
    return z, xs, Bm, Cm, dt_raw


def ssm_apply(p, x, cfg, ctx, chunk: int = 256, h0=None, return_state: bool = False):
    """Train/prefill SSD pass. x: (B,S,D) → (B,S,D) [+ (conv states, h_final)].

    Layout note: SSD is sequential over chunks, so the sequence axis must NOT
    be sharded here (unlike attention blocks, which are sequence-parallel).
    Projections are constrained to (batch→data, seq→replicated, d_inner→model):
    every device runs the full-sequence scan over its head slice — the natural
    TPU layout for SSD (heads are embarrassingly parallel, chunks are not).
    """
    B, S, D = x.shape
    d_inner, H, P, N = ssm_dims(cfg)
    if ctx is not None:
        x = ctx.constrain(x, ("ssm_batch", None, None))
    z, xs, Bm, Cm, dt_raw = _project(p, x, cfg)
    if ctx is not None:
        z = ctx.constrain(z, ("ssm_batch", None, "mlp"))
        xs = ctx.constrain(xs, ("ssm_batch", None, "mlp"))
        Bm = ctx.constrain(Bm, ("ssm_batch", None, None))
        Cm = ctx.constrain(Cm, ("ssm_batch", None, None))
        dt_raw = ctx.constrain(dt_raw, ("ssm_batch", None, "ssm_heads"))
    xs_c = _causal_conv(xs, p["conv_x"])
    Bm_c = _causal_conv(Bm, p["conv_B"])
    Cm_c = _causal_conv(Cm, p["conv_C"])
    xs_c = jax.nn.silu(xs_c)
    Bm_c = jax.nn.silu(Bm_c)
    Cm_c = jax.nn.silu(Cm_c)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xh = xs_c.reshape(B, S, H, P).astype(jnp.float32)
    y, h_final = ssd_chunked(xh, dt, A, Bm_c.astype(jnp.float32),
                             Cm_c.astype(jnp.float32), p["D_skip"], chunk, h0=h0)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": p["out_norm"]}, y, cfg.norm_eps)
    out = y @ p["w_out"]
    if not return_state:
        return out
    K = cfg.ssm_conv
    conv_states = {
        "x": jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))[:, S:S + K - 1, :],
        "B": jnp.pad(Bm, ((0, 0), (K - 1, 0), (0, 0)))[:, S:S + K - 1, :],
        "C": jnp.pad(Cm, ((0, 0), (K - 1, 0), (0, 0)))[:, S:S + K - 1, :],
    }
    return out, (conv_states, h_final)


def ssm_decode(p, x, cfg, ctx, conv_states, h):
    """Single-token decode. x: (B,1,D); conv states (B,K-1,·); h (B,H,P,N).

    Returns (out (B,1,D), new conv states, new h).
    """
    B = x.shape[0]
    d_inner, H, P, N = ssm_dims(cfg)
    z, xs, Bm, Cm, dt_raw = _project(p, x[:, 0, :], cfg)
    xs_t, cs_x = _conv_step(xs, conv_states["x"], p["conv_x"])
    Bm_t, cs_B = _conv_step(Bm, conv_states["B"], p["conv_B"])
    Cm_t, cs_C = _conv_step(Cm, conv_states["C"], p["conv_C"])
    xs_t = jax.nn.silu(xs_t)
    Bm_t = jax.nn.silu(Bm_t).astype(jnp.float32)
    Cm_t = jax.nn.silu(Cm_t).astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"][None, :])       # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = xs_t.reshape(B, H, P).astype(jnp.float32)
    decay = jnp.exp(dt * A[None, :])[:, :, None, None]
    inject = dt[:, :, None, None] * xh[..., None] * Bm_t[:, None, None, :]
    h_new = decay * h + inject
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cm_t) + p["D_skip"][None, :, None] * xh
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)[:, None, :]
    y = rmsnorm({"scale": p["out_norm"]}, y, cfg.norm_eps)
    out = y @ p["w_out"]
    return out, {"x": cs_x, "B": cs_B, "C": cs_C}, h_new
