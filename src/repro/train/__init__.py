from .loop import TrainLoop, init_train_state, make_train_step
from .checkpoint import save_checkpoint, load_checkpoint, all_steps
from .elastic import reshard_state, restore_elastic

__all__ = ["TrainLoop", "init_train_state", "make_train_step",
           "save_checkpoint", "load_checkpoint", "all_steps",
           "reshard_state", "restore_elastic"]
