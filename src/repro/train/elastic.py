"""Elastic scaling: checkpoints are topology-free, so a job can restart on a
different mesh (more/fewer data-parallel replicas, different pod count) by
re-sharding the restored state onto the new mesh.

``reshard_state`` is the single primitive: numpy tree + new mesh + logical
axes → device tree under the new topology.  Scale-down and scale-up are both
just restore-with-new-mesh; tests exercise 4→2→4 host devices.
"""

from __future__ import annotations

import jax

from repro import sharding
from repro.optim import adamw
from repro.train import checkpoint as ckpt_lib


def reshard_state(tree, model, opt_cfg, mesh, rules):
    """Place a host-side {params, opt} tree onto ``mesh`` per logical rules."""
    from repro.train.loop import state_shardings
    sh = state_shardings(model, opt_cfg, mesh, rules)
    return jax.device_put(tree, sh)


def restore_elastic(ckpt_dir, model, opt_cfg, mesh, rules, template):
    """Load newest checkpoint and re-shard it onto (a possibly different) mesh.

    Returns (state, step) or (None, None) when no checkpoint exists.
    """
    tree, step = ckpt_lib.load_checkpoint(ckpt_dir, template=template)
    if tree is None:
        return None, None
    return reshard_state(tree, model, opt_cfg, mesh, rules), step
