"""Training loop with paper-policy *fused-step phases*.

The paper combines several Apriori passes into one MapReduce job to amortize
per-job scheduling overhead.  The training-loop analogue: one jitted dispatch
executes ``npass`` complete optimizer steps via ``lax.scan`` over a stacked
batch — amortizing host→device dispatch, input transfer and per-step host
syncs.  The same Policy objects from :mod:`repro.core.policy` choose ``npass``
per phase (SPC = classic 1-step dispatch; VFPC/ETDPC adapt it).

"Skipped pruning" at this layer: the per-step NaN/metric host check is hoisted
out of the fused steps and performed once per phase (the phase-end support
filter).  A NaN'd phase is re-run from the phase-start checkpoint — integrity
comes from phase idempotence, exactly like the paper's job re-execution.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.core.policy import ALGORITHMS, PhaseStats
from repro.models.model import Model, ShardCtx
from repro.optim import adamw
from repro.train import checkpoint as ckpt_lib


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    mesh=None, rules=None, npass: int = 1, donate: bool = True):
    """Build the jitted fused train phase: (state, batches[npass]) → (state, metrics).

    With a mesh, in/out shardings are derived from logical axes and the state
    buffers are donated (in-place update on device).
    """
    ctx = ShardCtx(mesh, rules)

    def one_step(state, batch):
        def loss_fn(p):
            return model.loss(p, batch, ctx)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])
        new_params, new_opt, om = adamw.apply_updates(
            state["params"], grads, state["opt"], opt_cfg)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, **metrics, **om})

    def phase(state, batches):
        return jax.lax.scan(one_step, state, batches)

    if mesh is None:
        return jax.jit(phase, donate_argnums=(0,) if donate else ())

    state_sh = state_shardings(model, opt_cfg, mesh, rules)
    batch_axes = {k: (None,) + v for k, v in model.input_axes(
        _train_shape(model)).items()}
    batch_sh = {k: sharding.sharding_for(mesh, ax, rules)
                for k, ax in batch_axes.items()}
    return jax.jit(phase,
                   in_shardings=(state_sh, batch_sh),
                   out_shardings=(state_sh, None),
                   donate_argnums=(0,) if donate else ())


def _train_shape(model: Model):
    from repro.configs.base import ShapeConfig
    return ShapeConfig("train", 1, 1, "train")  # axes only depend on kind


def state_shardings(model: Model, opt_cfg, mesh, rules):
    """NamedShardings for the {params, opt} state tree (shape-aware)."""
    p_shapes, p_axes = model.abstract_params()
    o_axes = adamw.state_axes(p_axes, opt_cfg)
    o_shapes = jax.eval_shape(lambda: adamw.init_state(p_shapes, opt_cfg))
    return {
        "params": sharding.tree_shardings(mesh, p_axes, rules, p_shapes),
        "opt": sharding.tree_shardings(mesh, o_axes, rules, o_shapes),
    }


def init_train_state(model: Model, opt_cfg: adamw.AdamWConfig, key,
                     mesh=None, rules=None):
    """Initialize (optionally sharded) {params, opt} state."""
    if mesh is None:
        params = model.init(key)
        return {"params": params, "opt": adamw.init_state(params, opt_cfg)}
    state_sh = state_shardings(model, opt_cfg, mesh, rules)

    def build(k):
        params = model.init(k)
        return {"params": params, "opt": adamw.init_state(params, opt_cfg)}

    return jax.jit(build, out_shardings=state_sh)(key)


@dataclasses.dataclass
class TrainPhaseRecord:
    phase_idx: int
    npass: int
    steps: tuple
    elapsed: float
    mean_loss: float
    renan: bool = False


class TrainLoop:
    """Host driver: policy-controlled fused phases + checkpoint/restart."""

    def __init__(self, model, pipeline, opt_cfg=None, algorithm: str = "vfpc",
                 mesh=None, rules=None, checkpoint_dir: str | None = None,
                 ckpt_every_phases: int = 4, max_npass: int = 8,
                 policy_kwargs: dict | None = None):
        self.model = model
        self.pipeline = pipeline
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.mesh, self.rules = mesh, rules
        policy_cls, self.optimized = ALGORITHMS[algorithm]
        self.policy = policy_cls(**(policy_kwargs or {}))
        self.algorithm = algorithm
        self.checkpoint_dir = checkpoint_dir
        self.ckpt_every = ckpt_every_phases
        self.max_npass = max_npass
        self._steps = {}   # npass -> jitted phase fn
        self.records: list[TrainPhaseRecord] = []
        self.history: list[PhaseStats] = []

    def _phase_fn(self, npass: int):
        if npass not in self._steps:
            self._steps[npass] = make_train_step(
                self.model, self.opt_cfg, self.mesh, self.rules, npass=npass)
        return self._steps[npass]

    def _stack_batches(self, npass: int):
        toks, labs = [], []
        for _ in range(npass):
            t, l = self.pipeline.next_batch()
            toks.append(t)
            labs.append(l)
        batch = {"tokens": np.stack(toks), "labels": np.stack(labs)}
        cfg = self.model.cfg
        if cfg.frontend == "vision_stub":
            batch["vision_embeds"] = np.zeros(
                (npass, toks[0].shape[0], cfg.n_frontend_tokens, cfg.d_model),
                ml_bf16())
        if cfg.frontend == "audio_stub":
            batch["frame_embeds"] = np.zeros(
                (npass, toks[0].shape[0], cfg.enc_seq, cfg.d_model), ml_bf16())
        return batch

    def run(self, state, total_steps: int):
        """Run until ``total_steps`` optimizer steps. Returns (state, records)."""
        self.restore_data_cursor()
        done = int(jax.device_get(state["opt"]["step"]))
        phase_idx = len(self.records)
        while done < total_steps:
            prev = self.history[-1] if self.history else None
            prev2 = self.history[-2] if len(self.history) > 1 else None
            mode, val = self.policy.decide(prev, prev2)
            if mode == "width":
                npass = int(val)
            else:  # budget α → do-while semantics (see serving engine)
                npass = int(np.floor(val)) + 1
            npass = max(1, min(npass, self.max_npass, total_steps - done))

            batches = self._stack_batches(npass)
            fn = self._phase_fn(npass)
            t0 = time.perf_counter()
            state, metrics = fn(state, batches)
            losses = np.asarray(jax.device_get(metrics["loss"]))
            elapsed = time.perf_counter() - t0

            renan = False
            if not np.isfinite(losses).all():
                # phase-end integrity check failed → restore and re-run single
                renan = True
                if self.checkpoint_dir:
                    state = self.restore_or(state)
            else:
                done += npass
            tokens = npass * batches["tokens"].shape[1] * batches["tokens"].shape[2]
            self.history.append(PhaseStats(tokens, tokens // max(npass, 1), elapsed))
            self.records.append(TrainPhaseRecord(
                phase_idx, npass, (done - npass, done), elapsed,
                float(losses.mean()), renan))
            phase_idx += 1
            if self.checkpoint_dir and phase_idx % self.ckpt_every == 0:
                self._save(state, done)
        if self.checkpoint_dir:
            self._save(state, done)
        return state, self.records

    def _save(self, state, done: int):
        """Checkpoint model/opt state + the data-pipeline cursor, so a restart
        continues the token stream instead of replaying it."""
        import json, os
        ckpt_lib.save_checkpoint(self.checkpoint_dir, done, state)
        with open(os.path.join(self.checkpoint_dir, "data_state.json"), "w") as f:
            json.dump({"data_step": int(getattr(self.pipeline, "_step", 0)),
                       "opt_step": done}, f)

    def restore_data_cursor(self):
        """Fast-forward the pipeline to the checkpointed position (no-op if
        no checkpoint or the pipeline has already advanced)."""
        import json, os
        path = os.path.join(self.checkpoint_dir or "", "data_state.json")
        if self.checkpoint_dir and os.path.exists(path) \
                and getattr(self.pipeline, "_step", 0) == 0:
            with open(path) as f:
                self.pipeline._step = json.load(f)["data_step"]

    def restore_or(self, state):
        tmpl = jax.tree.map(lambda x: x, state)
        tree, step = ckpt_lib.load_checkpoint(self.checkpoint_dir, template=tmpl)
        if tree is None:
            return state
        if self.mesh is not None:
            sh = state_shardings(self.model, self.opt_cfg, self.mesh, self.rules)
            return jax.device_put(tree, sh)
        return jax.device_put(tree)


def ml_bf16():
    import ml_dtypes
    return ml_dtypes.bfloat16
