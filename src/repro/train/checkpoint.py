"""Topology-free checkpointing.

A checkpoint is a directory of raw little-endian leaf buffers plus a JSON
manifest (tree paths, shapes, dtypes, step).  Writes are atomic (tmp dir +
rename) so a crash mid-save never corrupts the latest checkpoint; restarts
resume from the newest complete step directory.  Checkpoints store full
(host-gathered) arrays and carry no mesh information — restore re-shards onto
whatever mesh the new job runs (see elastic.py), which is what makes
elastic scaling work.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np
import ml_dtypes  # ships with jax


def _leaf_path(keypath) -> str:
    parts = []
    for k in keypath:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_checkpoint(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    """Save a pytree. Returns the step directory path."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (kp, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.bin"
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(arr.tobytes())
        manifest["leaves"].append({
            "path": _leaf_path(kp), "file": fname,
            "shape": list(arr.shape), "dtype": str(arr.dtype),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
    return final


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def load_checkpoint(ckpt_dir: str, step: int | None = None, template=None):
    """Load a checkpoint as a pytree of numpy arrays.

    ``template``: a pytree with the same structure (e.g. from
    ``jax.eval_shape``) used to rebuild the tree; required.
    Returns (tree, step).
    """
    steps = all_steps(ckpt_dir)
    if not steps:
        return None, None
    step = step if step is not None else steps[-1]
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = []
    for entry in manifest["leaves"]:
        dtype = np.dtype(entry["dtype"]) if entry["dtype"] != "bfloat16" \
            else np.dtype(ml_dtypes.bfloat16)
        with open(os.path.join(d, entry["file"]), "rb") as f:
            arr = np.frombuffer(f.read(), dtype=dtype).reshape(entry["shape"])
        leaves.append(arr)
    if template is None:
        raise ValueError("template tree required to restore structure")
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
