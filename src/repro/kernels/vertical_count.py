"""Pallas TPU kernel: vertical (item-major) popcount-AND support counting.

Vertical layout (DESIGN.md §3): row ``i`` of the vertical DB is the bitmap of
transactions containing item ``i``; ``support(candidate) = popcount(AND of its
item rows)``.  Work per candidate is ``O(k · N/32)`` words instead of the
horizontal ``O(N · W)`` — the vertical data layout of Jen et al. (related work
[15] of the paper).

Kernel design (replaces the gather-heavy jnp scan):

* grid ``(C, Tw // bt, kmax)`` — candidates × transaction-word blocks × item
  slots, with the item-slot axis **innermost** so a ``(1, bt)`` VMEM scratch
  accumulator can AND the candidate's item rows for one transaction block
  before flushing a popcount partial sum into the ``(1,)`` output block
  (revisit-accumulate over both inner axes).
* the ``(C, kmax)`` candidate→row index table is **scalar-prefetched**
  (``PrefetchScalarGridSpec``), so the vertical-DB BlockSpec's index map picks
  item row ``idx[c, j]`` directly and each row block is DMA'd into VMEM by the
  pipeline — no gather instruction in the kernel body at all.
* padded candidate slots point at the valid-transaction mask row (the AND
  identity), and transaction-word padding is zeros (contributes 0 popcount),
  so no correction terms are needed.

VMEM per step: one ``(1, bt)`` row block + the ``(1, bt)`` accumulator — tiny;
``bt`` is lane-dim tiling (multiples of 128, default 512).  The jnp fallback
(`vertical_count_jnp`, §Perf iteration M-D) remains the production path on
CPU, where Pallas runs in interpret mode for validation only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 2048   # candidate block of the jnp scan fallback
DEFAULT_BT = 512       # transaction words per block (lane dim, multiple of 128)


def _vertical_count_kernel(idx_ref, row_ref, o_ref, acc_ref, *, kmax: int):
    del idx_ref  # consumed by the index maps (scalar prefetch)
    t = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((t == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(j == 0)
    def _load():
        acc_ref[...] = row_ref[...]

    @pl.when(j > 0)
    def _and():
        acc_ref[...] &= row_ref[...]

    @pl.when(j == kmax - 1)
    def _flush():
        o_ref[...] += jax.lax.population_count(
            acc_ref[...]).astype(jnp.int32).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def vertical_count_pallas(vdb: jax.Array, cand_idx: jax.Array,
                          bt: int = DEFAULT_BT,
                          interpret: bool = False) -> jax.Array:
    """Support counts from the vertical layout via the Pallas kernel.

    Args:
      vdb:      (I+1, Tw) uint32 item-major bitmaps; row I is the
                valid-transaction mask (AND identity used for padded slots).
      cand_idx: (C, kmax) int32 item ids per candidate, padded with I.
      bt:       transaction words per block (clamped to the padded Tw).

    Returns: (C,) int32 counts.
    """
    C, kmax = cand_idx.shape
    _, tw = vdb.shape
    # Clamp the block to the (128-aligned) word count so tiny DBs don't pad to
    # a full default block, then zero-pad words up to the block multiple.
    bt = min(bt, max(((tw + 127) // 128) * 128, 128))
    pad = (-tw) % bt
    if pad:
        vdb = jnp.concatenate(
            [vdb, jnp.zeros((vdb.shape[0], pad), vdb.dtype)], axis=1)
    grid = (C, vdb.shape[1] // bt, kmax)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, bt), lambda c, t, j, idx: (idx[c, j], t))],
        out_specs=pl.BlockSpec((1,), lambda c, t, j, idx: (c,)),
        scratch_shapes=[pltpu.VMEM((1, bt), jnp.uint32)],
    )
    return pl.pallas_call(
        functools.partial(_vertical_count_kernel, kmax=kmax),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C,), jnp.int32),
        interpret=interpret,
    )(cand_idx.astype(jnp.int32), vdb.astype(jnp.uint32))


def vertical_count_jnp(vdb: jax.Array, cand_idx: jax.Array,
                       block: int = DEFAULT_BLOCK) -> jax.Array:
    """Blocked jnp oracle/fallback: gather item rows, AND, popcount.

    Scans candidate blocks so peak memory is ``O(block · kmax · Tw)``.
    """
    vdb = jnp.asarray(vdb)          # host arrays are fine (oracle/bench use)
    cand_idx = jnp.asarray(cand_idx)
    C, kmax = cand_idx.shape
    pad = (-C) % block
    if pad:
        cand_idx = jnp.concatenate(
            [cand_idx, jnp.full((pad, kmax), vdb.shape[0] - 1,
                                cand_idx.dtype)], axis=0)
    blocks = cand_idx.reshape(-1, block, kmax)

    def body(_, idx_blk):
        rows = vdb[idx_blk]                          # (block, kmax, Tw)
        acc = rows[:, 0]
        for j in range(1, kmax):                     # kmax tiny: unrolled ANDs
            acc = acc & rows[:, j]
        cnt = jax.lax.population_count(acc).astype(jnp.int32).sum(-1)
        return None, cnt

    _, counts = jax.lax.scan(body, None, blocks)
    return counts.reshape(-1)[:C]


# ---------------------------------------------------------------------------
# Matmul (bit-plane int8 dot_general) formulation — DESIGN.md §10.
#
# The candidate→item index table becomes a 0/1 membership matrix A (C, I)
# (scatter; duplicate slots collapse, matching the AND semantics of the
# popcount form), the vertical DB a bit matrix V (I, Tn); then
#
#     present[c, t] = Σ_i A[c, i] · V[i, t]         (one int8 matmul)
#     match[c, t]   = present[c, t] == Σ_i A[c, i]  ∧  valid[t]
#     count[c]      = Σ_t match[c, t]
#
# Sentinel-padded slots never enter A, so the valid-transaction row plays the
# same role as in the popcount form (padded txn columns are all-zero and the
# empty candidate counts exactly the valid transactions).
# ---------------------------------------------------------------------------


def _vertical_membership(idx_blk: jax.Array, n_items: int):
    """(block, kmax) ids (sentinel = n_items) → 0/1 (block, I) int8 + per-row
    distinct-item counts (block,) int32."""
    blk = idx_blk.shape[0]
    A = jnp.zeros((blk, n_items + 1), jnp.int8).at[
        jnp.arange(blk)[:, None], idx_blk].set(1)
    A = A[:, :n_items]                               # drop the sentinel column
    return A, A.sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block",))
def vertical_count_matmul(vdb: jax.Array, cand_idx: jax.Array,
                          block: int = DEFAULT_BLOCK) -> jax.Array:
    """Blocked-jnp matmul twin of :func:`vertical_count_jnp` (bit-exact)."""
    from repro.core.bitset import junpack_bits
    vdb = jnp.asarray(vdb)
    cand_idx = jnp.asarray(cand_idx)
    I1, _ = vdb.shape
    n_items = I1 - 1
    C, kmax = cand_idx.shape
    vbits = junpack_bits(vdb)                        # (I+1, Tn) int8
    item_bits = vbits[:n_items]
    valid = vbits[n_items] > 0                       # (Tn,) bool
    pad = (-C) % block
    if pad:
        cand_idx = jnp.concatenate(
            [cand_idx, jnp.full((pad, kmax), n_items, cand_idx.dtype)], axis=0)
    blocks = cand_idx.reshape(-1, block, kmax)

    def body(_, idx_blk):
        A, nreal = _vertical_membership(idx_blk, n_items)
        ov = jax.lax.dot_general(A, item_bits, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.int32)
        match = (ov == nreal[:, None]) & valid[None, :]
        return None, match.sum(axis=1, dtype=jnp.int32)

    _, counts = jax.lax.scan(body, None, blocks)
    return counts.reshape(-1)[:C]


def _vertical_matmul_kernel(a_ref, n_ref, v_ref, val_ref, o_ref):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ov = jax.lax.dot_general(a_ref[...], v_ref[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.int32)  # (BC, BT)
    match = (ov == n_ref[...][:, None]) & (val_ref[...][None, :] > 0)
    o_ref[...] += match.sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bc", "bt", "interpret"))
def vertical_count_matmul_pallas(vdb: jax.Array, cand_idx: jax.Array,
                                 bc: int = 256, bt: int = 512,
                                 interpret: bool = False) -> jax.Array:
    """Vertical matmul counting as a Pallas kernel: (BC, I) × (I, BT) int8
    dots on the MXU, candidates tiled over the grid's first axis and
    transaction columns over the second (the item axis stays whole — catalogs
    are small next to the transaction axis)."""
    from repro.core.bitset import junpack_bits
    vdb = jnp.asarray(vdb)
    cand_idx = jnp.asarray(cand_idx)
    I1, _ = vdb.shape
    n_items = I1 - 1
    C, kmax = cand_idx.shape
    vbits = junpack_bits(vdb)
    item_bits, valid = vbits[:n_items], vbits[n_items]
    A, nreal = _vertical_membership(cand_idx, n_items)
    pad_c = (-C) % bc
    if pad_c:
        A = jnp.concatenate([A, jnp.zeros((pad_c, n_items), A.dtype)], axis=0)
        # a padded candidate row would count every valid txn (empty-set
        # semantics); poison its width so it never matches instead
        nreal = jnp.concatenate(
            [nreal, jnp.full((pad_c,), -1, nreal.dtype)])
    Tn = item_bits.shape[1]
    pad_t = (-Tn) % bt
    if pad_t:
        item_bits = jnp.concatenate(
            [item_bits, jnp.zeros((n_items, pad_t), item_bits.dtype)], axis=1)
        valid = jnp.concatenate([valid, jnp.zeros((pad_t,), valid.dtype)])
    grid = (A.shape[0] // bc, item_bits.shape[1] // bt)
    out = pl.pallas_call(
        _vertical_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, n_items), lambda ci, ti: (ci, 0)),
            pl.BlockSpec((bc,), lambda ci, ti: (ci,)),
            pl.BlockSpec((n_items, bt), lambda ci, ti: (0, ti)),
            pl.BlockSpec((bt,), lambda ci, ti: (ti,)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda ci, ti: (ci,)),
        out_shape=jax.ShapeDtypeStruct((A.shape[0],), jnp.int32),
        interpret=interpret,
    )(A, nreal, item_bits, valid)
    return out[:C]
