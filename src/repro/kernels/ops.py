"""Jit'd public wrappers around the Pallas kernels, with shape padding and a
memory-safe blocked-jnp fallback used on non-TPU backends.

``support_count(cands, txns, impl=...)``
  impl="pallas"  — the Pallas kernel (interpret=True automatically off-TPU).
  impl="jnp"     — blocked pure-jnp path (XLA-vectorized; default on CPU).
  impl="matmul"  — blocked bit-plane int8 dot_general form (DESIGN.md §10;
                   the tensor-core-native formulation, default on GPU).
  impl="matmul_pallas" — the matmul form as a Pallas MXU kernel.
  impl="auto"    — pallas on TPU, matmul on GPU, else jnp.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .support_count import (support_count_matmul, support_count_matmul_pallas,
                            support_count_pallas, DEFAULT_BC, DEFAULT_BT)


def _backend() -> str:
    return jax.default_backend()


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)


def _empty_cand_correction(cands: jax.Array, n_pad_rows: int) -> jax.Array:
    """Zero-padded txn rows spuriously match EMPTY candidates — subtract them."""
    if n_pad_rows == 0:
        return jnp.zeros((cands.shape[0],), jnp.int32)
    is_empty = (cands == 0).all(axis=1)
    return jnp.where(is_empty, jnp.int32(n_pad_rows), jnp.int32(0))


@functools.partial(jax.jit, static_argnames=("block",))
def _support_count_jnp(cands: jax.Array, txns: jax.Array, block: int = 4096) -> jax.Array:
    """Blocked jnp path: scan transaction chunks, accumulate counts.

    Memory: O(C * block) per step instead of O(C * T).
    """
    C, W = cands.shape
    n_pad = (-txns.shape[0]) % block
    txns = _pad_rows(txns, block)
    chunks = txns.reshape(-1, block, W)

    def body(acc, chunk):
        c = cands[:, None, :]
        t = chunk[None, :, :]
        match = jnp.all((c & t) == c, axis=-1)
        return acc + match.sum(axis=1).astype(jnp.int32), None

    init = jnp.zeros((C,), jnp.int32)
    acc, _ = jax.lax.scan(body, init, chunks)
    return acc - _empty_cand_correction(cands, n_pad)


def support_count(cands, txns, impl: str = "auto",
                  bc: int = DEFAULT_BC, bt: int = DEFAULT_BT) -> jax.Array:
    """Count, for each bitmask candidate, the transactions that contain it.

    Args:
      cands: (C, W) uint32 candidate bitmasks (any array-like).
      txns:  (T, W) uint32 transaction bitmasks.
      impl:  "auto" | "pallas" | "jnp".

    Returns:
      (C,) int32 support counts.

    Padding notes: rows are zero-padded to the block multiples.  A zero
    *transaction* row contains no non-empty candidate, so it never inflates a
    real candidate's count; zero *candidate* rows are sliced off before return.
    """
    cands = jnp.asarray(np.asarray(cands), dtype=jnp.uint32)
    txns = jnp.asarray(np.asarray(txns), dtype=jnp.uint32)
    C = cands.shape[0]
    if C == 0:
        return jnp.zeros((0,), jnp.int32)
    if impl == "auto":
        backend = _backend()
        impl = {"tpu": "pallas", "gpu": "matmul"}.get(backend, "jnp")
    if impl == "jnp":
        return _support_count_jnp(cands, txns)
    if impl == "matmul":
        return support_count_matmul(cands, txns)
    if impl in ("pallas", "matmul_pallas", "pallas_interpret",
                "matmul_pallas_interpret"):
        interpret = impl.endswith("_interpret") or _backend() != "tpu"
        n_pad = (-txns.shape[0]) % bt
        cp = _pad_rows(cands, bc)
        tp = _pad_rows(txns, bt)
        fn = (support_count_matmul_pallas if impl.startswith("matmul")
              else support_count_pallas)
        out = fn(cp, tp, bc=bc, bt=bt, interpret=interpret)[:C]
        return out - _empty_cand_correction(cands, n_pad)
    raise ValueError(f"unknown impl {impl!r}")
