"""Pallas TPU kernel: bitmask subset-match support counting.

This is the compute hot-spot of every MapReduce phase (the paper's ``subset()``
scan — the per-mapper pass over its transaction split).  The TPU-native design
replaces the prefix-tree walk with a dense word-parallel subset test:

    match[i, j] = AND_w ( (cand[i, w] & txn[j, w]) == cand[i, w] )
    count[i]    = sum_j match[i, j]

Tiling: candidates are tiled ``(BC, W)`` and transactions ``(BT, W)`` into VMEM;
the ``(BC, BT)`` match tile is reduced over the transaction grid axis into an
``(BC,)`` accumulator that stays resident in the output block across the inner
grid dimension (standard revisit-accumulate pattern).  ``W`` (words per bitmask,
= ceil(n_items/32)) is small and static, so the word loop fully unrolls and all
intermediates are 2-D ``(BC, BT)`` — aligned with the (8, 128) VPU tile.

VMEM footprint per grid step (defaults BC=256, BT=512, W≤8, uint32):
  cands 256·8·4 = 8 KiB, txns 512·8·4 = 16 KiB, match tile 256·512·4 = 512 KiB,
  accumulator 1 KiB → well under the ~16 MiB VMEM budget; BT can be raised to
  2048 on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BC = 256
DEFAULT_BT = 512


def _support_count_kernel(c_ref, t_ref, o_ref, *, n_words: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ok = None
    for w in range(n_words):  # static unroll, W is tiny
        cw = c_ref[:, w][:, None]          # (BC, 1)
        tw = t_ref[:, w][None, :]          # (1, BT)
        eq = (cw & tw) == cw               # (BC, BT)
        ok = eq if ok is None else (ok & eq)
    o_ref[...] += ok.sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bc", "bt", "interpret"))
def support_count_pallas(cands: jax.Array, txns: jax.Array,
                         bc: int = DEFAULT_BC, bt: int = DEFAULT_BT,
                         interpret: bool = False) -> jax.Array:
    """Support counts via the Pallas kernel.

    Shapes must be pre-padded: C % bc == 0 and T % bt == 0 (see ops.py wrapper).
    """
    C, W = cands.shape
    T, Wt = txns.shape
    assert W == Wt, (W, Wt)
    assert C % bc == 0 and T % bt == 0, (C, bc, T, bt)
    grid = (C // bc, T // bt)
    return pl.pallas_call(
        functools.partial(_support_count_kernel, n_words=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, W), lambda ci, ti: (ci, 0)),
            pl.BlockSpec((bt, W), lambda ci, ti: (ti, 0)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda ci, ti: (ci,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.int32),
        interpret=interpret,
    )(cands.astype(jnp.uint32), txns.astype(jnp.uint32))
