"""Pallas TPU kernel: bitmask subset-match support counting.

This is the compute hot-spot of every MapReduce phase (the paper's ``subset()``
scan — the per-mapper pass over its transaction split).  The TPU-native design
replaces the prefix-tree walk with a dense word-parallel subset test:

    match[i, j] = AND_w ( (cand[i, w] & txn[j, w]) == cand[i, w] )
    count[i]    = sum_j match[i, j]

Tiling: candidates are tiled ``(BC, W)`` and transactions ``(BT, W)`` into VMEM;
the ``(BC, BT)`` match tile is reduced over the transaction grid axis into an
``(BC,)`` accumulator that stays resident in the output block across the inner
grid dimension (standard revisit-accumulate pattern).  ``W`` (words per bitmask,
= ceil(n_items/32)) is small and static, so the word loop fully unrolls and all
intermediates are 2-D ``(BC, BT)`` — aligned with the (8, 128) VPU tile.

VMEM footprint per grid step (defaults BC=256, BT=512, W≤8, uint32):
  cands 256·8·4 = 8 KiB, txns 512·8·4 = 16 KiB, match tile 256·512·4 = 512 KiB,
  accumulator 1 KiB → well under the ~16 MiB VMEM budget; BT can be raised to
  2048 on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BC = 256
DEFAULT_BT = 512
DEFAULT_MATMUL_BLOCK = 2048   # txn rows per dot_general chunk (jnp matmul form)


def _support_count_kernel(c_ref, t_ref, o_ref, *, n_words: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ok = None
    for w in range(n_words):  # static unroll, W is tiny
        cw = c_ref[:, w][:, None]          # (BC, 1)
        tw = t_ref[:, w][None, :]          # (1, BT)
        eq = (cw & tw) == cw               # (BC, BT)
        ok = eq if ok is None else (ok & eq)
    o_ref[...] += ok.sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bc", "bt", "interpret"))
def support_count_pallas(cands: jax.Array, txns: jax.Array,
                         bc: int = DEFAULT_BC, bt: int = DEFAULT_BT,
                         interpret: bool = False) -> jax.Array:
    """Support counts via the Pallas kernel.

    Shapes must be pre-padded: C % bc == 0 and T % bt == 0 (see ops.py wrapper).
    """
    C, W = cands.shape
    T, Wt = txns.shape
    assert W == Wt, (W, Wt)
    assert C % bc == 0 and T % bt == 0, (C, bc, T, bt)
    grid = (C // bc, T // bt)
    return pl.pallas_call(
        functools.partial(_support_count_kernel, n_words=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, W), lambda ci, ti: (ci, 0)),
            pl.BlockSpec((bt, W), lambda ci, ti: (ti, 0)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda ci, ti: (ci,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.int32),
        interpret=interpret,
    )(cands.astype(jnp.uint32), txns.astype(jnp.uint32))


# ---------------------------------------------------------------------------
# Matmul (bit-plane int8 dot_general) formulation — DESIGN.md §10.
#
# Containment counting as a matmul: with C_b = junpack_bits(cands) (C, B) and
# T_b = junpack_bits(txns) (T, B), B = W·32,
#
#     overlap[i, j] = Σ_b C_b[i, b] · T_b[j, b] = |cand_i ∩ txn_j|
#     match[i, j]   = overlap[i, j] == popcount(cand_i)
#     count[i]      = Σ_j match[i, j]
#
# All arithmetic is integer, so the form is bit-exact against the popcount
# impls; the dominant cost is an (C, B) × (B, T) int8 matmul the MXU/tensor
# cores were built for, instead of a VPU bitwise-op stream.
# ---------------------------------------------------------------------------

_DOT_LAST = (((1,), (1,)), ((), ()))      # contract the bit-plane axis of both


@functools.partial(jax.jit, static_argnames=("block",))
def support_count_matmul(cands: jax.Array, txns: jax.Array,
                         block: int = DEFAULT_MATMUL_BLOCK) -> jax.Array:
    """Blocked-jnp matmul twin: scan txn chunks, int8 dot_general per chunk.

    Memory: O(C · block) int32 overlap per step instead of O(C · T).
    Semantics match ``_support_count_jnp`` exactly (internal zero-pad rows
    that spuriously match empty candidates are subtracted before return).
    """
    from repro.core.bitset import jpopcount_rows, junpack_bits
    C, W = cands.shape
    cands = cands.astype(jnp.uint32)
    cb = junpack_bits(cands)                          # (C, B) int8
    widths = jpopcount_rows(cands)                    # (C,) int32
    n_pad = (-txns.shape[0]) % block
    if n_pad:
        txns = jnp.concatenate(
            [txns, jnp.zeros((n_pad, W), txns.dtype)], axis=0)
    chunks = txns.astype(jnp.uint32).reshape(-1, block, W)

    def body(acc, chunk):
        tb = junpack_bits(chunk)                      # (block, B) int8
        ov = jax.lax.dot_general(cb, tb, _DOT_LAST,
                                 preferred_element_type=jnp.int32)
        return acc + (ov == widths[:, None]).sum(axis=1, dtype=jnp.int32), None

    init = jnp.zeros((C,), jnp.int32)
    acc, _ = jax.lax.scan(body, init, chunks)
    # zero-padded txn rows overlap 0 == width 0: they match (only) empty
    # candidates — subtract, mirroring ops._empty_cand_correction
    return acc - jnp.where(widths == 0, jnp.int32(n_pad), jnp.int32(0))


def _support_count_matmul_kernel(c_ref, w_ref, t_ref, o_ref):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ov = jax.lax.dot_general(c_ref[...], t_ref[...], _DOT_LAST,
                             preferred_element_type=jnp.int32)   # (BC, BT)
    o_ref[...] += (ov == w_ref[...][:, None]).sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bc", "bt", "interpret"))
def support_count_matmul_pallas(cands: jax.Array, txns: jax.Array,
                                bc: int = DEFAULT_BC, bt: int = DEFAULT_BT,
                                interpret: bool = False) -> jax.Array:
    """Support counts via the bit-plane matmul Pallas kernel (MXU form).

    Bit planes are unpacked outside the kernel (HBM int8 matrices, B = W·32
    columns); each grid step does one (BC, B) × (B, BT) int8 ``dot_general``
    into the MXU and an equality-compare reduce on the VPU.  Shapes must be
    pre-padded: C % bc == 0 and T % bt == 0 (see ops.py wrapper).
    """
    from repro.core.bitset import jpopcount_rows, junpack_bits
    C, W = cands.shape
    T, Wt = txns.shape
    assert W == Wt, (W, Wt)
    assert C % bc == 0 and T % bt == 0, (C, bc, T, bt)
    cands = cands.astype(jnp.uint32)
    cb = junpack_bits(cands)                      # (C, B) int8
    tb = junpack_bits(txns.astype(jnp.uint32))    # (T, B) int8
    widths = jpopcount_rows(cands)                # (C,) int32
    B = cb.shape[1]
    grid = (C // bc, T // bt)
    return pl.pallas_call(
        _support_count_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, B), lambda ci, ti: (ci, 0)),
            pl.BlockSpec((bc,), lambda ci, ti: (ci,)),
            pl.BlockSpec((bt, B), lambda ci, ti: (ti, 0)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda ci, ti: (ci,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.int32),
        interpret=interpret,
    )(cb, widths, tb)
