"""Pallas TPU kernel: antecedent-containment rule scoring (DESIGN.md §7).

Role-swapped reuse of the support-count subset test (§2/§3): rule antecedents
play the candidates and query baskets play the transactions, but instead of
reducing matches over the transaction axis the kernel emits the full masked
score matrix

    out[q, r] = score[r]  if ante[r] ⊆ basket[q]
                          (and, with ``exclude_contained``, cons[r] ⊄ basket[q])
                -inf      otherwise

ready for a device-side ``lax.top_k`` per query.  The consequent-containment
("nothing new to recommend") test rides in the same word loop, so novelty
filtering costs one extra AND/compare per word instead of a second pass over
the (Q, R) matrix.

Tiling mirrors ``support_count.py``: rules tiled ``(BR, W)`` and baskets
``(BQ, W)`` into VMEM, one ``(BQ, BR)`` float32 output tile per grid step, the
word loop statically unrolled (W is tiny).  No accumulation across grid steps
— every tile is written exactly once.  The blocked-jnp twin
(:func:`rule_scores_jnp`, the CPU production path and bit-exactness oracle)
scans basket chunks with the same select, so both paths produce identical
float32 bits.  Block sizes are autotuned via ``kernels/autotune.py`` (§5)
under the ``rules_jnp`` / ``rules_pallas`` impl keys.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 256       # baskets per tile (sublane dim)
DEFAULT_BR = 512       # rules per tile (lane dim)
DEFAULT_Q_BLOCK = 1024  # basket chunk of the jnp scan


def _rule_scores_kernel(a_ref, c_ref, s_ref, b_ref, o_ref, *, n_words: int,
                        exclude_contained: bool):
    ok = None
    bad = None
    for w in range(n_words):  # static unroll, W is tiny
        aw = a_ref[:, w][None, :]          # (1, BR)
        bw = b_ref[:, w][:, None]          # (BQ, 1)
        m = (aw & bw) == aw                # (BQ, BR) antecedent ⊆ basket
        ok = m if ok is None else (ok & m)
        if exclude_contained:
            cw = c_ref[:, w][None, :]
            mc = (cw & bw) == cw           # consequent ⊆ basket — nothing new
            bad = mc if bad is None else (bad & mc)
    if exclude_contained:
        ok = ok & jnp.logical_not(bad)
    o_ref[...] = jnp.where(ok, s_ref[...][None, :], -jnp.inf)


@functools.partial(jax.jit,
                   static_argnames=("bq", "br", "exclude_contained",
                                    "interpret"))
def rule_scores_pallas(antes: jax.Array, cons: jax.Array, scores: jax.Array,
                       baskets: jax.Array, bq: int = DEFAULT_BQ,
                       br: int = DEFAULT_BR, exclude_contained: bool = True,
                       interpret: bool = False) -> jax.Array:
    """Masked rule-score matrix via the Pallas kernel.

    Args:
      antes:   (R, W) uint32 antecedent bitmasks.
      cons:    (R, W) uint32 consequent bitmasks (read only when
               ``exclude_contained``).
      scores:  (R,) float32 rank keys (confidence·lift).
      baskets: (Q, W) uint32 query bitmasks.

    Returns: (Q, R) float32 — ``scores[r]`` where rule r fires for basket q,
    ``-inf`` elsewhere.

    Rows are padded internally: pad rules get an empty antecedent (matches
    everything) but a ``-inf`` score, and — with ``exclude_contained`` — an
    empty consequent (contained in everything), so they can never surface;
    pad baskets are sliced off before return.
    """
    R, W = antes.shape
    Q, Wb = baskets.shape
    assert W == Wb, (W, Wb)
    pad_r = (-R) % br
    if pad_r:
        zrow = jnp.zeros((pad_r, W), antes.dtype)
        antes = jnp.concatenate([antes, zrow], axis=0)
        cons = jnp.concatenate([cons, zrow], axis=0)
        scores = jnp.concatenate(
            [scores, jnp.full((pad_r,), -jnp.inf, scores.dtype)])
    pad_q = (-Q) % bq
    if pad_q:
        baskets = jnp.concatenate(
            [baskets, jnp.zeros((pad_q, W), baskets.dtype)], axis=0)
    Rp, Qp = antes.shape[0], baskets.shape[0]
    grid = (Qp // bq, Rp // br)
    out = pl.pallas_call(
        functools.partial(_rule_scores_kernel, n_words=W,
                          exclude_contained=exclude_contained),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, W), lambda qi, ri: (ri, 0)),
            pl.BlockSpec((br, W), lambda qi, ri: (ri, 0)),
            pl.BlockSpec((br,), lambda qi, ri: (ri,)),
            pl.BlockSpec((bq, W), lambda qi, ri: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((bq, br), lambda qi, ri: (qi, ri)),
        out_shape=jax.ShapeDtypeStruct((Qp, Rp), jnp.float32),
        interpret=interpret,
    )(antes.astype(jnp.uint32), cons.astype(jnp.uint32),
      scores.astype(jnp.float32), baskets.astype(jnp.uint32))
    return out[:Q, :R]


@functools.partial(jax.jit, static_argnames=("q_block", "exclude_contained"))
def rule_scores_jnp(antes: jax.Array, cons: jax.Array, scores: jax.Array,
                    baskets: jax.Array, q_block: int = DEFAULT_Q_BLOCK,
                    exclude_contained: bool = True) -> jax.Array:
    """Blocked jnp twin of :func:`rule_scores_pallas` (bit-exact agreement).

    Scans basket chunks so peak memory is ``O(q_block · R · W)`` instead of
    ``O(Q · R · W)``.
    """
    R, W = antes.shape
    Q = baskets.shape[0]
    antes = antes.astype(jnp.uint32)
    cons = cons.astype(jnp.uint32)
    scores = scores.astype(jnp.float32)
    pad_q = (-Q) % q_block
    if pad_q:
        baskets = jnp.concatenate(
            [baskets, jnp.zeros((pad_q, W), baskets.dtype)], axis=0)
    chunks = baskets.astype(jnp.uint32).reshape(-1, q_block, W)

    def body(_, blk):                       # blk: (q_block, W)
        ok = jnp.all((antes[None, :, :] & blk[:, None, :]) == antes[None, :, :],
                     axis=-1)
        if exclude_contained:
            ok &= jnp.logical_not(jnp.all(
                (cons[None, :, :] & blk[:, None, :]) == cons[None, :, :],
                axis=-1))
        return None, jnp.where(ok, scores[None, :], -jnp.inf)

    _, out = jax.lax.scan(body, None, chunks)
    return out.reshape(-1, R)[:Q]


# ---------------------------------------------------------------------------
# Matmul (bit-plane int8 dot_general) formulation — DESIGN.md §10.
#
# Containment via the overlap identity: with B_b (Q, B) basket bit planes and
# A_b (R, B) antecedent planes, ante[r] ⊆ basket[q] iff
# Σ_b B_b[q,b]·A_b[r,b] == popcount(ante[r]).  The consequent-novelty test is
# a second matmul against the consequent planes (one fused (Q,B)×(B,2R) dot
# would also work, but two dots keep the tiny-W case readable and XLA fuses
# the compare/select either way).  Integer overlaps → float32 select bits
# identical to the popcount twins.
# ---------------------------------------------------------------------------

_DOT_LAST = (((1,), (1,)), ((), ()))      # contract the bit-plane axis of both


@functools.partial(jax.jit, static_argnames=("q_block", "exclude_contained"))
def rule_scores_matmul(antes: jax.Array, cons: jax.Array, scores: jax.Array,
                       baskets: jax.Array, q_block: int = DEFAULT_Q_BLOCK,
                       exclude_contained: bool = True) -> jax.Array:
    """Blocked-jnp matmul twin of :func:`rule_scores_jnp` (bit-exact)."""
    from repro.core.bitset import jpopcount_rows, junpack_bits
    R, W = antes.shape
    Q = baskets.shape[0]
    antes = antes.astype(jnp.uint32)
    cons = cons.astype(jnp.uint32)
    scores = scores.astype(jnp.float32)
    ab = junpack_bits(antes)                          # (R, B) int8
    aw = jpopcount_rows(antes)                        # (R,) int32
    if exclude_contained:
        cb = junpack_bits(cons)
        cw = jpopcount_rows(cons)
    pad_q = (-Q) % q_block
    if pad_q:
        baskets = jnp.concatenate(
            [baskets, jnp.zeros((pad_q, W), baskets.dtype)], axis=0)
    chunks = baskets.astype(jnp.uint32).reshape(-1, q_block, W)

    def body(_, blk):                       # blk: (q_block, W)
        bb = junpack_bits(blk)                        # (q_block, B) int8
        ov = jax.lax.dot_general(bb, ab, _DOT_LAST,
                                 preferred_element_type=jnp.int32)
        ok = ov == aw[None, :]
        if exclude_contained:
            ovc = jax.lax.dot_general(bb, cb, _DOT_LAST,
                                      preferred_element_type=jnp.int32)
            ok &= ovc != cw[None, :]
        return None, jnp.where(ok, scores[None, :], -jnp.inf)

    _, out = jax.lax.scan(body, None, chunks)
    return out.reshape(-1, R)[:Q]


def _rule_scores_matmul_kernel(a_ref, aw_ref, c_ref, cw_ref, s_ref, b_ref,
                               o_ref, *, exclude_contained: bool):
    ov = jax.lax.dot_general(b_ref[...], a_ref[...], _DOT_LAST,
                             preferred_element_type=jnp.int32)   # (BQ, BR)
    ok = ov == aw_ref[...][None, :]
    if exclude_contained:
        ovc = jax.lax.dot_general(b_ref[...], c_ref[...], _DOT_LAST,
                                  preferred_element_type=jnp.int32)
        ok &= ovc != cw_ref[...][None, :]
    o_ref[...] = jnp.where(ok, s_ref[...][None, :], -jnp.inf)


@functools.partial(jax.jit,
                   static_argnames=("bq", "br", "exclude_contained",
                                    "interpret"))
def rule_scores_matmul_pallas(antes: jax.Array, cons: jax.Array,
                              scores: jax.Array, baskets: jax.Array,
                              bq: int = DEFAULT_BQ, br: int = DEFAULT_BR,
                              exclude_contained: bool = True,
                              interpret: bool = False) -> jax.Array:
    """Masked rule-score matrix via the bit-plane matmul Pallas kernel.

    Same pad semantics as :func:`rule_scores_pallas`: pad rules get empty
    antecedents (overlap 0 == width 0 → match everything) with ``-inf``
    scores and empty consequents (never novel under ``exclude_contained``),
    pad baskets are sliced off before return.
    """
    from repro.core.bitset import jpopcount_rows, junpack_bits
    R, W = antes.shape
    Q, Wb = baskets.shape
    assert W == Wb, (W, Wb)
    pad_r = (-R) % br
    if pad_r:
        zrow = jnp.zeros((pad_r, W), antes.dtype)
        antes = jnp.concatenate([antes, zrow], axis=0)
        cons = jnp.concatenate([cons, zrow], axis=0)
        scores = jnp.concatenate(
            [scores, jnp.full((pad_r,), -jnp.inf, scores.dtype)])
    pad_q = (-Q) % bq
    if pad_q:
        baskets = jnp.concatenate(
            [baskets, jnp.zeros((pad_q, W), baskets.dtype)], axis=0)
    antes = antes.astype(jnp.uint32)
    cons = cons.astype(jnp.uint32)
    ab, aw = junpack_bits(antes), jpopcount_rows(antes)
    cb, cw = junpack_bits(cons), jpopcount_rows(cons)
    bb = junpack_bits(baskets.astype(jnp.uint32))
    B = ab.shape[1]
    Rp, Qp = antes.shape[0], baskets.shape[0]
    grid = (Qp // bq, Rp // br)
    out = pl.pallas_call(
        functools.partial(_rule_scores_matmul_kernel,
                          exclude_contained=exclude_contained),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, B), lambda qi, ri: (ri, 0)),
            pl.BlockSpec((br,), lambda qi, ri: (ri,)),
            pl.BlockSpec((br, B), lambda qi, ri: (ri, 0)),
            pl.BlockSpec((br,), lambda qi, ri: (ri,)),
            pl.BlockSpec((br,), lambda qi, ri: (ri,)),
            pl.BlockSpec((bq, B), lambda qi, ri: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((bq, br), lambda qi, ri: (qi, ri)),
        out_shape=jax.ShapeDtypeStruct((Qp, Rp), jnp.float32),
        interpret=interpret,
    )(ab, aw, cb, cw, scores.astype(jnp.float32), bb)
    return out[:Q, :R]
