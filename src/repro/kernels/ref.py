"""Pure-jnp oracles for the Pallas kernels (small-shape ground truth)."""

from __future__ import annotations

import jax.numpy as jnp


def support_count_ref(cands: jnp.ndarray, txns: jnp.ndarray) -> jnp.ndarray:
    """Support counts of bitmask candidates over bitmask transactions.

    Args:
      cands: (C, W) uint32 — candidate itemset bitmasks.
      txns:  (T, W) uint32 — transaction bitmasks.

    Returns:
      (C,) int32 — for each candidate, the number of transactions t with
      candidate ⊆ t, i.e. ``all_w((c & t) == c)``.
    """
    c = cands[:, None, :]
    t = txns[None, :, :]
    match = jnp.all((c & t) == c, axis=-1)  # (C, T)
    return match.sum(axis=1).astype(jnp.int32)
