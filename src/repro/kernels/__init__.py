"""Pallas TPU kernels for the counting hot-spot (+ jnp oracles and wrappers).

Every kernel comes in two formulations (DESIGN.md §10): the popcount-AND
subset test and the bit-plane int8 ``dot_general`` ("matmul") twin, each with
a blocked-jnp oracle and a Pallas variant.  ``autotune.tuned_plan`` picks the
fastest family per (backend, shape bucket).
"""

from .autotune import tuned_blocks, tuned_plan
from .delta_count import (delta_count, delta_count_jnp, delta_count_matmul,
                          delta_count_matmul_pallas, delta_count_pallas)
from .ops import support_count
from .ref import support_count_ref
from .rule_match import (rule_scores_jnp, rule_scores_matmul,
                         rule_scores_matmul_pallas, rule_scores_pallas)
from .support_count import support_count_matmul, support_count_matmul_pallas
from .vertical_count import (vertical_count_jnp, vertical_count_matmul,
                             vertical_count_matmul_pallas,
                             vertical_count_pallas)

__all__ = ["support_count", "support_count_ref", "tuned_blocks", "tuned_plan",
           "support_count_matmul", "support_count_matmul_pallas",
           "delta_count", "delta_count_jnp", "delta_count_pallas",
           "delta_count_matmul", "delta_count_matmul_pallas",
           "rule_scores_jnp", "rule_scores_pallas",
           "rule_scores_matmul", "rule_scores_matmul_pallas",
           "vertical_count_jnp", "vertical_count_pallas",
           "vertical_count_matmul", "vertical_count_matmul_pallas"]
