"""Pallas TPU kernels for the counting hot-spot (+ jnp oracles and wrappers)."""

from .ops import support_count
from .ref import support_count_ref

__all__ = ["support_count", "support_count_ref"]
