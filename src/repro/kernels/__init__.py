"""Pallas TPU kernels for the counting hot-spot (+ jnp oracles and wrappers)."""

from .autotune import tuned_blocks
from .ops import support_count
from .ref import support_count_ref
from .vertical_count import vertical_count_jnp, vertical_count_pallas

__all__ = ["support_count", "support_count_ref", "tuned_blocks",
           "vertical_count_jnp", "vertical_count_pallas"]
