"""Pallas TPU kernels for the counting hot-spot (+ jnp oracles and wrappers)."""

from .autotune import tuned_blocks
from .delta_count import delta_count, delta_count_jnp, delta_count_pallas
from .ops import support_count
from .ref import support_count_ref
from .rule_match import rule_scores_jnp, rule_scores_pallas
from .vertical_count import vertical_count_jnp, vertical_count_pallas

__all__ = ["support_count", "support_count_ref", "tuned_blocks",
           "delta_count", "delta_count_jnp", "delta_count_pallas",
           "rule_scores_jnp", "rule_scores_pallas",
           "vertical_count_jnp", "vertical_count_pallas"]
