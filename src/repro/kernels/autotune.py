"""Block-size autotuner for the counting kernels.

The best tile/block sizes for the counting hot-spot depend on backend and on
the phase's shape regime (candidate rows × transaction rows/words) — exactly
the knobs the paper turns by re-sizing Hadoop input splits.  On first use per
``(backend, impl, shape-bucket)`` key the tuner times a small config sweep on
synthetic data and caches the winner:

* in-process (dict) — so a mining run tunes each bucket at most once;
* on disk (JSON at ``~/.cache/repro/autotune.json``, override with
  ``REPRO_AUTOTUNE_CACHE``) — so later processes skip the sweep entirely.

``REPRO_AUTOTUNE=0`` disables timing and returns the static defaults.
Interpret-mode Pallas (and the Pallas kernels off-TPU generally) are never
timed: interpret timings are meaningless, so defaults are returned.

Cache format (DESIGN.md §5, §9)::

    {"cpu:cpu/vertical/C4096/T1024/W8/k5": {"block": 2048}, ...}

Keys lead with the concrete device identity (``backend:device_kind`` from
``costmodel.measure.device_key``), not just the JAX backend name — a cache
written on one TPU generation must not silently pin block sizes on another.
Legacy ``backend/...`` entries written before device-kind keying are migrated
in place: adopted under the new key on first lookup, no re-sweep.  The timing
loop itself is the shared ``costmodel.measure.time_once`` (one measurement
discipline across autotuner and cost model).

Shape buckets are next-pow2 of the padded candidate/transaction extents, so a
whole mining run touches only a handful of keys.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.costmodel.measure import device_key, time_once

DEFAULTS = {
    "jnp": {"txn_block": 4096},
    "matmul": {"txn_block": 2048},
    "pallas": {"bc": 256, "bt": 512},
    "pallas_interpret": {"bc": 256, "bt": 512},
    "matmul_pallas": {"bc": 256, "bt": 512},
    "matmul_pallas_interpret": {"bc": 256, "bt": 512},
    "vertical": {"block": 2048},
    "vertical_matmul": {"block": 2048},
    "vertical_pallas": {"bt": 512},
    "vertical_pallas_interpret": {"bt": 512},
    "vertical_matmul_pallas": {"bc": 256, "bt": 512},
    "vertical_matmul_pallas_interpret": {"bc": 256, "bt": 512},
    "rules_jnp": {"q_block": 1024},
    "rules_matmul": {"q_block": 1024},
    "rules_pallas": {"bq": 256, "br": 512},
    "rules_pallas_interpret": {"bq": 256, "br": 512},
    "rules_matmul_pallas": {"bq": 256, "br": 512},
    "rules_matmul_pallas_interpret": {"bq": 256, "br": 512},
    "delta_jnp": {"txn_block": 1024},
    "delta_matmul": {"txn_block": 1024},
    "delta_pallas": {"bc": 256, "bt": 256},
    "delta_pallas_interpret": {"bc": 256, "bt": 256},
    "delta_matmul_pallas": {"bc": 256, "bt": 256},
    "delta_matmul_pallas_interpret": {"bc": 256, "bt": 256},
}

CONFIGS = {
    "jnp": [{"txn_block": b} for b in (1024, 4096, 16384)],
    "matmul": [{"txn_block": b} for b in (512, 2048, 8192)],
    "pallas": [{"bc": bc, "bt": bt}
               for bc, bt in ((128, 512), (256, 512), (256, 1024))],
    "matmul_pallas": [{"bc": bc, "bt": bt}
                      for bc, bt in ((128, 512), (256, 512), (256, 1024))],
    "vertical": [{"block": b} for b in (512, 2048, 8192)],
    "vertical_matmul": [{"block": b} for b in (512, 2048, 8192)],
    "vertical_pallas": [{"bt": b} for b in (512, 1024, 2048)],
    "vertical_matmul_pallas": [{"bc": bc, "bt": bt}
                               for bc, bt in ((128, 512), (256, 512),
                                              (256, 1024))],
    "rules_jnp": [{"q_block": b} for b in (256, 1024, 4096)],
    "rules_matmul": [{"q_block": b} for b in (256, 1024, 4096)],
    "rules_pallas": [{"bq": bq, "br": br}
                     for bq, br in ((128, 512), (256, 512), (256, 1024))],
    "rules_matmul_pallas": [{"bq": bq, "br": br}
                            for bq, br in ((128, 512), (256, 512),
                                           (256, 1024))],
    "delta_jnp": [{"txn_block": b} for b in (256, 1024, 4096)],
    "delta_matmul": [{"txn_block": b} for b in (256, 1024, 4096)],
    "delta_pallas": [{"bc": bc, "bt": bt}
                     for bc, bt in ((128, 256), (256, 256), (256, 512))],
    "delta_matmul_pallas": [{"bc": bc, "bt": bt}
                            for bc, bt in ((128, 256), (256, 256),
                                           (256, 512))],
}

# -- cross-family plans (DESIGN.md §10) ---------------------------------------
#
# ``tuned_plan`` searches *across* implementation families (popcount vs
# matmul, horizontal vs vertical, jnp vs Pallas) at one shape bucket and
# persists the overall winner — the per-family ``tuned_blocks`` sweep only
# picks block sizes *within* a family, which is how the BENCH own-goal of a
# tuned-but-43×-slower vertical config at C=256 happened.  The jnp baseline
# family is always timed, so the recorded winner can never lose to it.

PLAN_FAMILIES = {
    "count": ("jnp", "matmul", "vertical", "vertical_matmul",
              "pallas", "matmul_pallas", "vertical_pallas",
              "vertical_matmul_pallas"),
    "delta": ("delta_jnp", "delta_matmul", "delta_pallas",
              "delta_matmul_pallas"),
    "rules": ("rules_jnp", "rules_matmul", "rules_pallas",
              "rules_matmul_pallas"),
}
PLAN_BASELINES = {"count": "jnp", "delta": "delta_jnp", "rules": "rules_jnp"}

# skip (never the baseline / predicted winner) families the calibrated cost
# model prices more than this factor above the predicted best — pure pruning
# of the timing sweep, not a substitute for measuring the finalists
PLAN_PRICE_SKIP = 8.0

# caps on the synthetic timing shapes: tuning must stay ≪ one counting job
_CAP_C = 4096
_CAP_T_ROWS = 8192     # horizontal: transaction rows
_CAP_T_WORDS = 2048    # vertical: transaction words (= 64k transactions)

# Cross-family plan sweeps time ONE config per family and persist the winner
# forever, so they can afford (nearly) true candidate extents.  Capped-shape
# timings mislead there: families scale differently past the cap (the
# vertical gather-scan is strongly sublinear in C while the horizontal path
# turns superlinear once the txn tile falls out of cache), so a C=16384 plan
# timed at C=4096 picks the wrong layout.
_PLAN_CAP_C = 16384

_memory_cache: dict = {}


def cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def _load_disk() -> dict:
    try:
        with open(cache_path()) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _save_disk(store: dict) -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(store, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # cache is best-effort; in-process dict still holds the winner


def _bucket(n: int) -> int:
    """Next power of two ≥ n (≥ 1)."""
    return 1 << max(int(n) - 1, 0).bit_length()


# timing now shared with the cost model; alias kept for older callers/tests
_time_once = time_once


def _candidate_runner(impl: str, C: int, T: int, W: int, kmax: int,
                      cap_c: int = _CAP_C):
    """Build per-config callables over synthetic data of the bucketed shape."""
    rng = np.random.default_rng(0)
    if impl in ("jnp", "matmul", "pallas", "matmul_pallas"):
        C = min(C, cap_c)
        T = min(T, _CAP_T_ROWS)
        cands = jnp.asarray(rng.integers(0, 2**32, (C, W), dtype=np.uint32))
        txns = jnp.asarray(rng.integers(0, 2**32, (T, W), dtype=np.uint32))
        if impl == "jnp":
            from .ops import _support_count_jnp

            def make(cfg):
                blk = min(cfg["txn_block"], T)
                return lambda: _support_count_jnp(cands, txns, block=blk)
        elif impl == "matmul":
            from .support_count import support_count_matmul

            def make(cfg):
                blk = min(cfg["txn_block"], T)
                return lambda: support_count_matmul(cands, txns, block=blk)
        else:
            from .support_count import (support_count_matmul_pallas,
                                        support_count_pallas)
            fn = (support_count_matmul_pallas if impl == "matmul_pallas"
                  else support_count_pallas)

            def make(cfg):
                bc = min(cfg["bc"], C)
                bt = cfg["bt"]
                tp = T + ((-T) % bt)
                tx = jnp.concatenate(
                    [txns, jnp.zeros((tp - T, W), txns.dtype)], axis=0)
                return lambda: fn(cands, tx, bc=bc, bt=bt)
        return make
    if impl in ("vertical", "vertical_matmul", "vertical_pallas",
                "vertical_matmul_pallas"):
        C = min(C, cap_c)
        Tw = min(T, _CAP_T_WORDS)
        n_items = max(W * 32 - 1, 1)
        vdb = rng.integers(0, 2**32, (n_items + 1, Tw), dtype=np.uint32)
        vdb[-1] = 0xFFFFFFFF                      # valid-transaction mask row
        vdb = jnp.asarray(vdb)
        idx = np.full((C, kmax), n_items, np.int32)
        for j in range(kmax):
            idx[:, j] = rng.integers(0, n_items, C)
        idx = jnp.asarray(idx)
        if impl in ("vertical", "vertical_matmul"):
            from .vertical_count import (vertical_count_jnp,
                                         vertical_count_matmul)
            fn = (vertical_count_matmul if impl == "vertical_matmul"
                  else vertical_count_jnp)

            def make(cfg):
                return lambda: fn(vdb, idx, block=cfg["block"])
        elif impl == "vertical_pallas":
            from .vertical_count import vertical_count_pallas

            def make(cfg):
                return lambda: vertical_count_pallas(vdb, idx, bt=cfg["bt"])
        else:
            from .vertical_count import vertical_count_matmul_pallas

            def make(cfg):
                bc = min(cfg["bc"], C)
                return lambda: vertical_count_matmul_pallas(
                    vdb, idx, bc=bc, bt=cfg["bt"])
        return make
    if impl in ("delta_jnp", "delta_matmul", "delta_pallas",
                "delta_matmul_pallas"):
        C = min(C, cap_c)
        T = min(T, _CAP_T_ROWS)       # slab rows (added + evicted)
        cands = jnp.asarray(rng.integers(0, 2**32, (C, W), dtype=np.uint32))
        txns = jnp.asarray(rng.integers(0, 2**32, (T, W), dtype=np.uint32))
        signs = jnp.asarray(rng.choice(np.array([-1, 1], np.int32), T))
        if impl in ("delta_jnp", "delta_matmul"):
            from .delta_count import delta_count_jnp, delta_count_matmul
            fn = (delta_count_matmul if impl == "delta_matmul"
                  else delta_count_jnp)

            def make(cfg):
                blk = min(cfg["txn_block"], T)
                return lambda: fn(cands, txns, signs, block=blk)
        else:
            from .delta_count import (delta_count_matmul_pallas,
                                      delta_count_pallas)
            fn = (delta_count_matmul_pallas if impl == "delta_matmul_pallas"
                  else delta_count_pallas)

            def make(cfg):
                bc = min(cfg["bc"], C)
                bt = cfg["bt"]
                tp = T + ((-T) % bt)
                tx = jnp.concatenate(
                    [txns, jnp.zeros((tp - T, W), txns.dtype)], axis=0)
                sg = jnp.concatenate(
                    [signs, jnp.zeros((tp - T,), signs.dtype)])
                return lambda: fn(cands, tx, sg, bc=bc, bt=bt)
        return make
    if impl in ("rules_jnp", "rules_matmul", "rules_pallas",
                "rules_matmul_pallas"):
        R = min(C, cap_c)             # rules play the candidate role
        Q = min(T, _CAP_T_ROWS)        # baskets play the transaction role
        antes = rng.integers(0, 2**32, (R, W), dtype=np.uint32)
        cons = rng.integers(0, 2**32, (R, W), dtype=np.uint32) & ~antes
        scores = jnp.asarray(rng.random(R, dtype=np.float32))
        antes, cons = jnp.asarray(antes), jnp.asarray(cons)
        baskets = jnp.asarray(rng.integers(0, 2**32, (Q, W), dtype=np.uint32))
        if impl in ("rules_jnp", "rules_matmul"):
            from .rule_match import rule_scores_jnp, rule_scores_matmul
            fn = (rule_scores_matmul if impl == "rules_matmul"
                  else rule_scores_jnp)

            def make(cfg):
                qb = min(cfg["q_block"], Q)
                return lambda: fn(antes, cons, scores, baskets, q_block=qb)
        else:
            from .rule_match import (rule_scores_matmul_pallas,
                                     rule_scores_pallas)
            fn = (rule_scores_matmul_pallas if impl == "rules_matmul_pallas"
                  else rule_scores_pallas)

            def make(cfg):
                return lambda: fn(antes, cons, scores, baskets,
                                  bq=cfg["bq"], br=cfg["br"])
        return make
    raise ValueError(f"unknown impl {impl!r}")


def tuned_blocks(impl: str, *, C: int, T: int, W: int = 1, kmax: int = 1,
                 backend: str | None = None) -> dict:
    """Best block config for a counting job of the given shape bucket.

    Args:
      impl: any key of ``CONFIGS`` — the popcount families ("jnp", "pallas",
            "vertical", "rules_*", "delta_*") and their bit-plane "matmul"
            twins ("matmul", "matmul_pallas", "vertical_matmul", ...).
      C:    padded candidate rows.
      T:    transaction rows (horizontal impls) or words (vertical impls).
      W:    words per bitmask (horizontal) / of the item axis (vertical).
      kmax: items per candidate (vertical impls only).

    Returns a dict of keyword block sizes for the counting call.
    """
    backend = backend or jax.default_backend()
    untunable = (
        impl not in CONFIGS
        or impl.endswith("interpret")
        or ("pallas" in impl and backend != "tpu")
        or os.environ.get("REPRO_AUTOTUNE", "1") == "0"
    )
    if untunable:
        return dict(DEFAULTS.get(impl, {}))

    shape = f"{impl}/C{_bucket(C)}/T{_bucket(T)}/W{W}/k{kmax}"
    key = f"{device_key(backend)}/{shape}"
    if key in _memory_cache:
        return dict(_memory_cache[key])
    disk = _load_disk()
    if key in disk:
        _memory_cache[key] = dict(disk[key])
        return dict(disk[key])
    legacy = f"{backend}/{shape}"      # pre-device-kind cache entries
    if legacy in disk:
        disk[key] = dict(disk.pop(legacy))
        _memory_cache[key] = dict(disk[key])
        _save_disk(disk)
        return dict(disk[key])

    make = _candidate_runner(impl, _bucket(C), _bucket(T), W, kmax)
    best_cfg, best_t = None, float("inf")
    for cfg in CONFIGS[impl]:
        try:
            t = time_once(make(cfg))
        except Exception:       # a config can be invalid for exotic shapes
            continue
        if t < best_t:
            best_cfg, best_t = cfg, t
    if best_cfg is None:
        best_cfg = DEFAULTS[impl]
    _memory_cache[key] = dict(best_cfg)
    disk[key] = dict(best_cfg)
    _save_disk(disk)
    return dict(best_cfg)


def _family_shape(kind: str, family: str, C: int, T: int):
    """Per-family (C, T) timing shape: vertical families take transaction
    *words*, everything else rows; rules' T axis is query baskets."""
    if kind == "count" and family.startswith("vertical"):
        return C, max((T + 31) // 32, 1)
    return C, T


def _strip_family(kind: str, family: str) -> str:
    """Family key → the wrapper-level impl name callers dispatch on."""
    for prefix in ("delta_", "rules_"):
        if family.startswith(prefix):
            return family[len(prefix):]
    return family


def tuned_plan(kind: str, *, C: int, T: int, W: int = 1, kmax: int = 1,
               backend: str | None = None) -> dict | None:
    """Cross-family winner for one shape bucket (DESIGN.md §10).

    Args:
      kind: "count" (mining support counts — horizontal *and* vertical
            families compete), "delta" (streaming slabs), "rules" (serving).
      C:    candidate/rule rows.
      T:    transaction/basket *rows* (vertical families are timed at the
            equivalent word count internally).
      W:    words per bitmask.
      kmax: items per candidate (prices the vertical gather width).

    Returns ``{"impl": <wrapper impl name>, "blocks": {...}}`` — the measured
    argmin over every eligible family at its own tuned block sizes, with the
    jnp baseline always timed (the cross-check that fixes tuned-but-slower
    winners) — or None when ``REPRO_AUTOTUNE=0`` (callers fall back to their
    static per-backend default).  Winners are cached in-process and on disk
    under ``{device}/plan/...`` keys.  A calibrated cost model prunes
    families priced ≥ ``PLAN_PRICE_SKIP``× the predicted best from the sweep
    (never the baseline or the predicted winner).
    """
    if os.environ.get("REPRO_AUTOTUNE", "1") == "0":
        return None
    if kind not in PLAN_FAMILIES:
        raise ValueError(f"unknown plan kind {kind!r}; "
                         f"options: {tuple(PLAN_FAMILIES)}")
    backend = backend or jax.default_backend()
    families = [f for f in PLAN_FAMILIES[kind]
                if not ("pallas" in f and backend != "tpu")]
    baseline = PLAN_BASELINES[kind]
    shape = f"plan/{kind}/C{_bucket(C)}/T{_bucket(T)}/W{W}/k{kmax}"
    key = f"{device_key(backend)}/{shape}"
    if key in _memory_cache:
        return dict(_memory_cache[key])
    disk = _load_disk()
    if key in disk:
        _memory_cache[key] = dict(disk[key])
        return dict(disk[key])

    # cost-model pruning: families the calibrated fits price far above the
    # predicted best are skipped (timing still decides among the finalists)
    predicted: dict[str, float] = {}
    try:
        from repro.roofline import count_job_ops
        from repro.costmodel.model import default_model
        mdl = default_model()
        dev = device_key(backend)
        for fam in families:
            p = mdl.predict(f"{dev}/{_strip_family(kind, fam)}/count",
                            count_job_ops(C, T, W))
            if p is not None and p > 0:
                predicted[fam] = p
    except Exception:
        predicted = {}
    keep = set(families)
    if len(predicted) >= 2:
        pbest_fam = min(predicted, key=predicted.get)
        pbest = predicted[pbest_fam]
        keep = {f for f in families
                if f == baseline or f == pbest_fam
                or predicted.get(f, 0.0) < PLAN_PRICE_SKIP * pbest}

    timed_us: dict[str, float] = {}
    best_fam, best_blocks, best_t = None, None, float("inf")
    for fam in families:
        if fam not in keep:
            continue
        fc, ft = _family_shape(kind, fam, C, T)
        blocks = tuned_blocks(fam, C=fc, T=ft, W=W, kmax=kmax,
                              backend=backend)
        try:
            make = _candidate_runner(fam, _bucket(fc), _bucket(ft), W, kmax,
                                     cap_c=_PLAN_CAP_C)
            t = time_once(make(blocks))
        except Exception:       # a family can be invalid for exotic shapes
            continue
        timed_us[fam] = t * 1e6
        if t < best_t:
            best_fam, best_blocks, best_t = fam, blocks, t
    if best_fam is None:        # every family failed: fall back to baseline
        fc, ft = _family_shape(kind, baseline, C, T)
        best_fam = baseline
        best_blocks = tuned_blocks(baseline, C=fc, T=ft, W=W, kmax=kmax,
                                   backend=backend)
    plan = {"impl": _strip_family(kind, best_fam), "family": best_fam,
            "blocks": dict(best_blocks), "timed_us": timed_us}
    _memory_cache[key] = dict(plan)
    disk[key] = dict(plan)
    _save_disk(disk)
    return dict(plan)
