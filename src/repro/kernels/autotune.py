"""Block-size autotuner for the counting kernels.

The best tile/block sizes for the counting hot-spot depend on backend and on
the phase's shape regime (candidate rows × transaction rows/words) — exactly
the knobs the paper turns by re-sizing Hadoop input splits.  On first use per
``(backend, impl, shape-bucket)`` key the tuner times a small config sweep on
synthetic data and caches the winner:

* in-process (dict) — so a mining run tunes each bucket at most once;
* on disk (JSON at ``~/.cache/repro/autotune.json``, override with
  ``REPRO_AUTOTUNE_CACHE``) — so later processes skip the sweep entirely.

``REPRO_AUTOTUNE=0`` disables timing and returns the static defaults.
Interpret-mode Pallas (and the Pallas kernels off-TPU generally) are never
timed: interpret timings are meaningless, so defaults are returned.

Cache format (DESIGN.md §5, §9)::

    {"cpu:cpu/vertical/C4096/T1024/W8/k5": {"block": 2048}, ...}

Keys lead with the concrete device identity (``backend:device_kind`` from
``costmodel.measure.device_key``), not just the JAX backend name — a cache
written on one TPU generation must not silently pin block sizes on another.
Legacy ``backend/...`` entries written before device-kind keying are migrated
in place: adopted under the new key on first lookup, no re-sweep.  The timing
loop itself is the shared ``costmodel.measure.time_once`` (one measurement
discipline across autotuner and cost model).

Shape buckets are next-pow2 of the padded candidate/transaction extents, so a
whole mining run touches only a handful of keys.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.costmodel.measure import device_key, time_once

DEFAULTS = {
    "jnp": {"txn_block": 4096},
    "pallas": {"bc": 256, "bt": 512},
    "pallas_interpret": {"bc": 256, "bt": 512},
    "vertical": {"block": 2048},
    "vertical_pallas": {"bt": 512},
    "vertical_pallas_interpret": {"bt": 512},
    "rules_jnp": {"q_block": 1024},
    "rules_pallas": {"bq": 256, "br": 512},
    "rules_pallas_interpret": {"bq": 256, "br": 512},
    "delta_jnp": {"txn_block": 1024},
    "delta_pallas": {"bc": 256, "bt": 256},
    "delta_pallas_interpret": {"bc": 256, "bt": 256},
}

CONFIGS = {
    "jnp": [{"txn_block": b} for b in (1024, 4096, 16384)],
    "pallas": [{"bc": bc, "bt": bt}
               for bc, bt in ((128, 512), (256, 512), (256, 1024))],
    "vertical": [{"block": b} for b in (512, 2048, 8192)],
    "vertical_pallas": [{"bt": b} for b in (512, 1024, 2048)],
    "rules_jnp": [{"q_block": b} for b in (256, 1024, 4096)],
    "rules_pallas": [{"bq": bq, "br": br}
                     for bq, br in ((128, 512), (256, 512), (256, 1024))],
    "delta_jnp": [{"txn_block": b} for b in (256, 1024, 4096)],
    "delta_pallas": [{"bc": bc, "bt": bt}
                     for bc, bt in ((128, 256), (256, 256), (256, 512))],
}

# caps on the synthetic timing shapes: tuning must stay ≪ one counting job
_CAP_C = 4096
_CAP_T_ROWS = 8192     # horizontal: transaction rows
_CAP_T_WORDS = 2048    # vertical: transaction words (= 64k transactions)

_memory_cache: dict = {}


def cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def _load_disk() -> dict:
    try:
        with open(cache_path()) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _save_disk(store: dict) -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(store, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # cache is best-effort; in-process dict still holds the winner


def _bucket(n: int) -> int:
    """Next power of two ≥ n (≥ 1)."""
    return 1 << max(int(n) - 1, 0).bit_length()


# timing now shared with the cost model; alias kept for older callers/tests
_time_once = time_once


def _candidate_runner(impl: str, C: int, T: int, W: int, kmax: int):
    """Build per-config callables over synthetic data of the bucketed shape."""
    rng = np.random.default_rng(0)
    if impl in ("jnp", "pallas"):
        C = min(C, _CAP_C)
        T = min(T, _CAP_T_ROWS)
        cands = jnp.asarray(rng.integers(0, 2**32, (C, W), dtype=np.uint32))
        txns = jnp.asarray(rng.integers(0, 2**32, (T, W), dtype=np.uint32))
        if impl == "jnp":
            from .ops import _support_count_jnp

            def make(cfg):
                blk = min(cfg["txn_block"], T)
                return lambda: _support_count_jnp(cands, txns, block=blk)
        else:
            from .support_count import support_count_pallas

            def make(cfg):
                bc = min(cfg["bc"], C)
                bt = cfg["bt"]
                tp = T + ((-T) % bt)
                tx = jnp.concatenate(
                    [txns, jnp.zeros((tp - T, W), txns.dtype)], axis=0)
                return lambda: support_count_pallas(cands, tx, bc=bc, bt=bt)
        return make
    if impl in ("vertical", "vertical_pallas"):
        C = min(C, _CAP_C)
        Tw = min(T, _CAP_T_WORDS)
        n_items = max(W * 32 - 1, 1)
        vdb = rng.integers(0, 2**32, (n_items + 1, Tw), dtype=np.uint32)
        vdb[-1] = 0xFFFFFFFF                      # valid-transaction mask row
        vdb = jnp.asarray(vdb)
        idx = np.full((C, kmax), n_items, np.int32)
        for j in range(kmax):
            idx[:, j] = rng.integers(0, n_items, C)
        idx = jnp.asarray(idx)
        if impl == "vertical":
            from .vertical_count import vertical_count_jnp

            def make(cfg):
                return lambda: vertical_count_jnp(vdb, idx, block=cfg["block"])
        else:
            from .vertical_count import vertical_count_pallas

            def make(cfg):
                return lambda: vertical_count_pallas(vdb, idx, bt=cfg["bt"])
        return make
    if impl in ("delta_jnp", "delta_pallas"):
        C = min(C, _CAP_C)
        T = min(T, _CAP_T_ROWS)       # slab rows (added + evicted)
        cands = jnp.asarray(rng.integers(0, 2**32, (C, W), dtype=np.uint32))
        txns = jnp.asarray(rng.integers(0, 2**32, (T, W), dtype=np.uint32))
        signs = jnp.asarray(rng.choice(np.array([-1, 1], np.int32), T))
        if impl == "delta_jnp":
            from .delta_count import delta_count_jnp

            def make(cfg):
                blk = min(cfg["txn_block"], T)
                return lambda: delta_count_jnp(cands, txns, signs, block=blk)
        else:
            from .delta_count import delta_count_pallas

            def make(cfg):
                bc = min(cfg["bc"], C)
                bt = cfg["bt"]
                tp = T + ((-T) % bt)
                tx = jnp.concatenate(
                    [txns, jnp.zeros((tp - T, W), txns.dtype)], axis=0)
                sg = jnp.concatenate(
                    [signs, jnp.zeros((tp - T,), signs.dtype)])
                return lambda: delta_count_pallas(cands, tx, sg, bc=bc, bt=bt)
        return make
    if impl in ("rules_jnp", "rules_pallas"):
        R = min(C, _CAP_C)             # rules play the candidate role
        Q = min(T, _CAP_T_ROWS)        # baskets play the transaction role
        antes = rng.integers(0, 2**32, (R, W), dtype=np.uint32)
        cons = rng.integers(0, 2**32, (R, W), dtype=np.uint32) & ~antes
        scores = jnp.asarray(rng.random(R, dtype=np.float32))
        antes, cons = jnp.asarray(antes), jnp.asarray(cons)
        baskets = jnp.asarray(rng.integers(0, 2**32, (Q, W), dtype=np.uint32))
        if impl == "rules_jnp":
            from .rule_match import rule_scores_jnp

            def make(cfg):
                qb = min(cfg["q_block"], Q)
                return lambda: rule_scores_jnp(antes, cons, scores, baskets,
                                               q_block=qb)
        else:
            from .rule_match import rule_scores_pallas

            def make(cfg):
                return lambda: rule_scores_pallas(antes, cons, scores, baskets,
                                                  bq=cfg["bq"], br=cfg["br"])
        return make
    raise ValueError(f"unknown impl {impl!r}")


def tuned_blocks(impl: str, *, C: int, T: int, W: int = 1, kmax: int = 1,
                 backend: str | None = None) -> dict:
    """Best block config for a counting job of the given shape bucket.

    Args:
      impl: "jnp" | "pallas" | "pallas_interpret" | "vertical" |
            "vertical_pallas" | "vertical_pallas_interpret".
      C:    padded candidate rows.
      T:    transaction rows (horizontal impls) or words (vertical impls).
      W:    words per bitmask (horizontal) / of the item axis (vertical).
      kmax: items per candidate (vertical impls only).

    Returns a dict of keyword block sizes for the counting call.
    """
    backend = backend or jax.default_backend()
    untunable = (
        impl not in CONFIGS
        or impl.endswith("interpret")
        or (impl in ("pallas", "vertical_pallas", "rules_pallas",
                     "delta_pallas")
            and backend != "tpu")
        or os.environ.get("REPRO_AUTOTUNE", "1") == "0"
    )
    if untunable:
        return dict(DEFAULTS.get(impl, {}))

    shape = f"{impl}/C{_bucket(C)}/T{_bucket(T)}/W{W}/k{kmax}"
    key = f"{device_key(backend)}/{shape}"
    if key in _memory_cache:
        return dict(_memory_cache[key])
    disk = _load_disk()
    if key in disk:
        _memory_cache[key] = dict(disk[key])
        return dict(disk[key])
    legacy = f"{backend}/{shape}"      # pre-device-kind cache entries
    if legacy in disk:
        disk[key] = dict(disk.pop(legacy))
        _memory_cache[key] = dict(disk[key])
        _save_disk(disk)
        return dict(disk[key])

    make = _candidate_runner(impl, _bucket(C), _bucket(T), W, kmax)
    best_cfg, best_t = None, float("inf")
    for cfg in CONFIGS[impl]:
        try:
            t = time_once(make(cfg))
        except Exception:       # a config can be invalid for exotic shapes
            continue
        if t < best_t:
            best_cfg, best_t = cfg, t
    if best_cfg is None:
        best_cfg = DEFAULTS[impl]
    _memory_cache[key] = dict(best_cfg)
    disk[key] = dict(best_cfg)
    _save_disk(disk)
    return dict(best_cfg)
