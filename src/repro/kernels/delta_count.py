"""Pallas TPU kernel: signed delta support counting for streaming windows.

Streaming updates (DESIGN.md §8) change a transaction window by a micro-batch
of *added* and *evicted* transactions.  Support counts are sums over
transactions, so the new count of every tracked candidate is

    count'[i] = count[i] + |{t ∈ added : c_i ⊆ t}| − |{t ∈ evicted : c_i ⊆ t}|

and a window update only has to scan the O(delta) slab instead of the
O(window) database.  Both slabs are processed in one pass: transactions are
concatenated into a single ``(T, W)`` slab with a per-row sign vector
(+1 added, −1 evicted, 0 padding), and the kernel accumulates

    delta[i] = Σ_j sign[j] · [cand[i] ⊆ txn[j]]

Tiling mirrors ``support_count.py``: candidates ``(BC, W)`` × slab ``(BT, W)``
tiles in VMEM, the word loop statically unrolled, an ``(BC,)`` int32
accumulator revisited across the slab grid axis.  Sign-0 padding makes the
kernel self-correcting: zero-padded slab rows match empty (zero-padded)
candidate rows, but contribute 0 — so unlike ``support_count`` no
empty-candidate correction term is needed on either path.

The blocked-jnp twin (:func:`delta_count_jnp`) is bit-exact (integer
arithmetic only) and is the CPU production path; block sizes are autotuned
via ``kernels/autotune.py`` (§5) under the ``delta_jnp`` / ``delta_pallas``
impl keys.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.autotune import DEFAULTS, _bucket, tuned_blocks, tuned_plan

DEFAULT_BC = 256
DEFAULT_BT = 256
DEFAULT_TXN_BLOCK = 1024

DELTA_IMPLS = ("auto", "jnp", "pallas", "pallas_interpret", "matmul",
               "matmul_pallas", "matmul_pallas_interpret")

MIN_SLAB_BUCKET = 32       # pow2 slab padding floor — few compiled shapes


def _delta_count_kernel(c_ref, t_ref, s_ref, o_ref, *, n_words: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ok = None
    for w in range(n_words):  # static unroll, W is tiny
        cw = c_ref[:, w][:, None]          # (BC, 1)
        tw = t_ref[:, w][None, :]          # (1, BT)
        eq = (cw & tw) == cw               # (BC, BT)
        ok = eq if ok is None else (ok & eq)
    signed = jnp.where(ok, s_ref[...][None, :], jnp.int32(0))
    o_ref[...] += signed.sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bc", "bt", "interpret"))
def delta_count_pallas(cands: jax.Array, txns: jax.Array, signs: jax.Array,
                       bc: int = DEFAULT_BC, bt: int = DEFAULT_BT,
                       interpret: bool = False) -> jax.Array:
    """Signed delta counts via the Pallas kernel.

    Args:
      cands: (C, W) uint32 candidate bitmasks, C % bc == 0 (pre-padded).
      txns:  (T, W) uint32 slab bitmasks, T % bt == 0 (pre-padded).
      signs: (T,) int32 per-row sign: +1 added, −1 evicted, 0 padding.

    Returns: (C,) int32 signed count deltas.
    """
    C, W = cands.shape
    T, Wt = txns.shape
    assert W == Wt, (W, Wt)
    assert C % bc == 0 and T % bt == 0, (C, bc, T, bt)
    grid = (C // bc, T // bt)
    return pl.pallas_call(
        functools.partial(_delta_count_kernel, n_words=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, W), lambda ci, ti: (ci, 0)),
            pl.BlockSpec((bt, W), lambda ci, ti: (ti, 0)),
            pl.BlockSpec((bt,), lambda ci, ti: (ti,)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda ci, ti: (ci,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.int32),
        interpret=interpret,
    )(cands.astype(jnp.uint32), txns.astype(jnp.uint32),
      signs.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("block",))
def delta_count_jnp(cands: jax.Array, txns: jax.Array, signs: jax.Array,
                    block: int = DEFAULT_TXN_BLOCK) -> jax.Array:
    """Blocked jnp twin of :func:`delta_count_pallas` (bit-exact: int math).

    Scans slab chunks so peak memory is O(C · block) instead of O(C · T).
    """
    C, W = cands.shape
    pad = (-txns.shape[0]) % block
    if pad:
        txns = jnp.concatenate(
            [txns, jnp.zeros((pad, W), txns.dtype)], axis=0)
        signs = jnp.concatenate([signs, jnp.zeros((pad,), signs.dtype)])
    chunks = txns.reshape(-1, block, W)
    sign_chunks = signs.astype(jnp.int32).reshape(-1, block)

    def body(acc, xs):
        chunk, sgn = xs
        c = cands[:, None, :]
        t = chunk[None, :, :]
        match = jnp.all((c & t) == c, axis=-1)
        signed = jnp.where(match, sgn[None, :], jnp.int32(0))
        return acc + signed.sum(axis=1).astype(jnp.int32), None

    init = jnp.zeros((C,), jnp.int32)
    acc, _ = jax.lax.scan(body, init, (chunks, sign_chunks))
    return acc


# ---------------------------------------------------------------------------
# Matmul (bit-plane int8 dot_general) formulation — DESIGN.md §10.
#
# Same identity as support_count's matmul form, with the per-row sign folded
# into the reduction:  delta[i] = Σ_j sign[j] · [overlap[i,j] == width[i]].
# Sign-0 padding keeps the form self-correcting (zero slab rows match empty
# candidates but contribute 0), so like the popcount form no empty-candidate
# correction is needed.
# ---------------------------------------------------------------------------

_DOT_LAST = (((1,), (1,)), ((), ()))      # contract the bit-plane axis of both


@functools.partial(jax.jit, static_argnames=("block",))
def delta_count_matmul(cands: jax.Array, txns: jax.Array, signs: jax.Array,
                       block: int = DEFAULT_TXN_BLOCK) -> jax.Array:
    """Blocked-jnp matmul twin of :func:`delta_count_jnp` (bit-exact)."""
    from repro.core.bitset import jpopcount_rows, junpack_bits
    C, W = cands.shape
    cands = cands.astype(jnp.uint32)
    cb = junpack_bits(cands)                          # (C, B) int8
    widths = jpopcount_rows(cands)                    # (C,) int32
    pad = (-txns.shape[0]) % block
    if pad:
        txns = jnp.concatenate(
            [txns, jnp.zeros((pad, W), txns.dtype)], axis=0)
        signs = jnp.concatenate([signs, jnp.zeros((pad,), signs.dtype)])
    chunks = txns.astype(jnp.uint32).reshape(-1, block, W)
    sign_chunks = signs.astype(jnp.int32).reshape(-1, block)

    def body(acc, xs):
        chunk, sgn = xs
        tb = junpack_bits(chunk)                      # (block, B) int8
        ov = jax.lax.dot_general(cb, tb, _DOT_LAST,
                                 preferred_element_type=jnp.int32)
        signed = jnp.where(ov == widths[:, None], sgn[None, :], jnp.int32(0))
        return acc + signed.sum(axis=1).astype(jnp.int32), None

    init = jnp.zeros((C,), jnp.int32)
    acc, _ = jax.lax.scan(body, init, (chunks, sign_chunks))
    return acc


def _delta_count_matmul_kernel(c_ref, w_ref, t_ref, s_ref, o_ref):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ov = jax.lax.dot_general(c_ref[...], t_ref[...], _DOT_LAST,
                             preferred_element_type=jnp.int32)   # (BC, BT)
    signed = jnp.where(ov == w_ref[...][:, None], s_ref[...][None, :],
                       jnp.int32(0))
    o_ref[...] += signed.sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bc", "bt", "interpret"))
def delta_count_matmul_pallas(cands: jax.Array, txns: jax.Array,
                              signs: jax.Array, bc: int = DEFAULT_BC,
                              bt: int = DEFAULT_BT,
                              interpret: bool = False) -> jax.Array:
    """Signed delta counts via the bit-plane matmul Pallas kernel (MXU form).

    Same pre-padding contract as :func:`delta_count_pallas`.
    """
    from repro.core.bitset import jpopcount_rows, junpack_bits
    C, W = cands.shape
    T, Wt = txns.shape
    assert W == Wt, (W, Wt)
    assert C % bc == 0 and T % bt == 0, (C, bc, T, bt)
    cands = cands.astype(jnp.uint32)
    cb = junpack_bits(cands)
    tb = junpack_bits(txns.astype(jnp.uint32))
    widths = jpopcount_rows(cands)
    B = cb.shape[1]
    grid = (C // bc, T // bt)
    return pl.pallas_call(
        _delta_count_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, B), lambda ci, ti: (ci, 0)),
            pl.BlockSpec((bc,), lambda ci, ti: (ci,)),
            pl.BlockSpec((bt, B), lambda ci, ti: (ti, 0)),
            pl.BlockSpec((bt,), lambda ci, ti: (ti,)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda ci, ti: (ci,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.int32),
        interpret=interpret,
    )(cb, widths, tb, signs.astype(jnp.int32))


def build_slab(added: np.ndarray, evicted: np.ndarray,
               min_bucket: int = MIN_SLAB_BUCKET):
    """Concatenate add/evict slabs, pad rows to a pow2 bucket with sign 0.

    Returns ``(slab (Tp, W) uint32, signs (Tp,) int32)`` — pow2-bucketed so
    the streaming loop touches a handful of compiled slab shapes (§2).
    """
    added = np.asarray(added, np.uint32)
    evicted = np.asarray(evicted, np.uint32)
    W = added.shape[1] if added.ndim == 2 else evicted.shape[1]
    slab = np.concatenate([added, evicted], axis=0)
    signs = np.concatenate([np.ones(added.shape[0], np.int32),
                            -np.ones(evicted.shape[0], np.int32)])
    tp = max(min_bucket, _bucket(max(slab.shape[0], 1)))
    if tp != slab.shape[0]:
        slab = np.concatenate(
            [slab, np.zeros((tp - slab.shape[0], W), np.uint32)], axis=0)
        signs = np.concatenate(
            [signs, np.zeros(tp - signs.shape[0], np.int32)])
    return slab, signs


def delta_count(cands, added, evicted, impl: str = "auto",
                autotune: bool = True) -> np.ndarray:
    """Host wrapper: signed count delta per candidate for one window update.

    Args:
      cands:   (C, W) uint32 tracked candidate bitmasks (any row count —
               pre-bucket-padding them via ``phases.bucket_pad`` keeps the
               compiled-shape set small across a stream).
      added:   (A, W) uint32 transactions entering the window.
      evicted: (E, W) uint32 transactions leaving the window.
      impl:    one of ``DELTA_IMPLS`` ("auto": the autotuned cross-family
               plan winner when autotune is on, else pallas on TPU / jnp
               elsewhere; "*pallas" off-TPU degrades to interpret).

    Returns: (C,) int32 — add to the tracked int64 counts.
    """
    if impl not in DELTA_IMPLS:
        raise ValueError(f"unknown impl {impl!r}; options: {DELTA_IMPLS}")
    cands = np.asarray(cands, np.uint32)
    C, W = cands.shape
    if C == 0:
        return np.zeros((0,), np.int32)
    slab, signs = build_slab(added, evicted)
    if not signs.any():
        return np.zeros((C,), np.int32)
    backend = jax.default_backend()
    T = slab.shape[0]
    if impl == "auto":
        plan = tuned_plan("delta", C=C, T=T, W=W) if autotune else None
        if plan is not None:
            impl = plan["impl"]
        else:
            impl = "pallas" if backend == "tpu" else "jnp"
    if impl in ("jnp", "matmul"):
        key = f"delta_{impl}"
        blocks = (tuned_blocks(key, C=C, T=T, W=W) if autotune
                  else dict(DEFAULTS[key]))
        block = min(blocks["txn_block"], T)
        fn = delta_count_jnp if impl == "jnp" else delta_count_matmul
        out = fn(jnp.asarray(cands), jnp.asarray(slab),
                 jnp.asarray(signs), block=block)
        return np.asarray(out)
    matmul = impl.startswith("matmul")
    interpret = impl.endswith("_interpret") or backend != "tpu"
    base = "delta_matmul_pallas" if matmul else "delta_pallas"
    impl_key = f"{base}_interpret" if interpret else base
    blocks = (tuned_blocks(impl_key, C=C, T=T, W=W) if autotune
              else dict(DEFAULTS[impl_key]))
    bc = min(blocks["bc"], _bucket(C))
    bt = min(blocks["bt"], T)
    pad_c = (-C) % bc
    if pad_c:
        cands = np.concatenate(
            [cands, np.zeros((pad_c, W), np.uint32)], axis=0)
    pad_t = (-T) % bt
    if pad_t:
        slab = np.concatenate(
            [slab, np.zeros((pad_t, W), np.uint32)], axis=0)
        signs = np.concatenate([signs, np.zeros(pad_t, np.int32)])
    fn = delta_count_matmul_pallas if matmul else delta_count_pallas
    out = fn(jnp.asarray(cands), jnp.asarray(slab),
             jnp.asarray(signs), bc=bc, bt=bt, interpret=interpret)
    return np.asarray(out)[:C]
