"""SLO-aware admission, fair shedding, and result caching for rule serving
(DESIGN.md §12).

The closed-loop benchmark arms answer "how fast can the engine go"; this
module answers the production question — "what traffic can it sustain *while
meeting a latency SLO*".  Three mechanisms, layered in the order a query
meets them:

1. **Result cache** (:class:`ResultCache`): an LRU over
   ``(tenant, rule_version, frozen-basket, k)``.  Hot baskets skip the device
   entirely (outcome ``"cached"``, zero queueing).  Keying on the tenant's
   RuleStore *version counter* makes invalidation atomic and free: a
   :meth:`~repro.serving.rule_store.RuleStore.swap_rules` bumps the version,
   every stale entry simply stops being reachable, and other tenants' cached
   answers survive untouched.

2. **SLO admission** (:meth:`~repro.costmodel.CostController.should_admit`):
   predicted sojourn — device backlog already committed plus the calibrated
   cost-model prediction for the dispatch this query would join — against the
   ``latency_slo_ms`` target.  A query that would blow the SLO anyway is shed
   *on arrival* (outcome ``"shed"``), which is cheaper for everyone than
   serving it late: under overload, queueing theory says the queue otherwise
   grows without bound and every tenant misses.

3. **Fair shedding**: overload shedding alone lets one tenant's burst starve
   the rest.  When an arrival must shed but its tenant is *under* its fair
   share (1/n_active of admitted traffic), the newest queued query of the
   most over-share tenant is displaced instead — per-tenant max-min fairness
   with O(queue) bookkeeping, no token buckets.

The :class:`OpenLoopServer` drives all three under an **open-loop virtual
clock**: queries carry synthetic arrival timestamps, the device is a single
virtual resource (``busy_until``), and a dispatch's cost is either the real
measured serve time (benchmark mode) or a scripted ``dispatch_cost_fn``
(tier-1 tests — fully deterministic, no sleeps, no wall clock in the latency
math).  Latency = completion − arrival, so queueing delay is priced in, which
is exactly what the closed-loop arms hide.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

from repro.obs.clock import MonotonicClock
from repro.obs.metrics import Registry
from repro.obs.trace import current_tracer
from repro.roofline import XFER_OPS_PER_BYTE

from .rule_store import DEFAULT_TENANT


def basket_key(basket) -> tuple:
    """Canonical cache key for one basket: sorted de-duplicated item ids
    (bitset packing is set-semantics, so order/multiplicity never matter)."""
    return tuple(sorted(set(int(i) for i in basket)))


class ResultCache:
    """LRU result cache keyed by (tenant, rule version, basket, k).

    ``capacity <= 0`` disables caching (every get misses, puts are dropped).
    Entries for superseded rule versions are unreachable by construction —
    lookups always use the *current* version — and get evicted by LRU churn,
    so a swap invalidates a tenant's answers atomically without a scan.
    """

    def __init__(self, capacity: int = 256, registry: Registry | None = None):
        self.capacity = int(capacity)
        self._data: OrderedDict = OrderedDict()
        # hit/miss telemetry lives in a metrics registry (DESIGN.md §13);
        # a private one by default so unrelated caches never share counts
        self._metrics = registry if registry is not None else Registry()
        self._hits = self._metrics.counter("serving.cache_hits")
        self._misses = self._metrics.counter("serving.cache_misses")

    @property
    def hits(self) -> int:
        return int(self._hits.value)

    @property
    def misses(self) -> int:
        return int(self._misses.value)

    def __len__(self) -> int:
        return len(self._data)

    def get(self, tenant: str, version: int, basket, k: int):
        if self.capacity <= 0:
            return None
        key = (tenant, version, basket_key(basket), k)
        if key not in self._data:
            self._misses.inc()
            return None
        self._data.move_to_end(key)
        self._hits.inc()
        return self._data[key]

    def put(self, tenant: str, version: int, basket, k: int, recs) -> None:
        if self.capacity <= 0:
            return
        key = (tenant, version, basket_key(basket), k)
        self._data[key] = recs
        self._data.move_to_end(key)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)


@dataclasses.dataclass
class QueryOutcome:
    """What happened to one submitted query — the admission telemetry row."""
    seq: int
    tenant: str
    t_arrival: float
    outcome: str = "queued"       # → "served" | "cached" | "shed"
    t_done: float | None = None
    latency_s: float | None = None
    dispatch_idx: int | None = None
    n_fused: int | None = None    # queries fused into the answering dispatch
    results: list | None = dataclasses.field(default=None, repr=False)

    def as_dict(self) -> dict:
        return {"seq": self.seq, "tenant": self.tenant,
                "t_arrival": self.t_arrival, "outcome": self.outcome,
                "latency_ms": (None if self.latency_s is None
                               else self.latency_s * 1e3),
                "dispatch_idx": self.dispatch_idx, "n_fused": self.n_fused}


@dataclasses.dataclass
class _Pending:
    outcome: QueryOutcome
    basket: tuple
    decision: object | None       # admission Decision to backfill .measured


class OpenLoopServer:
    """Open-loop admission front-end over a :class:`RuleServeEngine`.

    Queries arrive with explicit timestamps (:meth:`submit`); the server
    caches / admits / sheds each one, micro-batches admitted queries, and
    advances a virtual device clock per dispatch.  Deterministic by
    construction: with a scripted ``dispatch_cost_fn`` no wall-clock value
    enters any latency, so tier-1 load tests assert exact numbers.

    Args:
      engine: the (single- or multi-tenant) RuleServeEngine to dispatch on.
      latency_slo_ms: admission target; None disables shedding (admit all).
      batch: dispatch when this many queries are queued.
      max_wait_ms: dispatch when the oldest queued query has waited this
        long (bounds tail latency under light load).
      cache_size: LRU entries (0 disables the result cache).
      fair_shedding: displace over-share tenants instead of shedding an
        under-share arrival.
      controller: CostController for admission predictions + telemetry;
        defaults to the engine's (admission needs one — without any, all
        queries are admitted).
      dispatch_cost_fn: ``(n_queries, work_ops) -> seconds`` override for the
        virtual dispatch cost; None measures the real serve call.
      top_k: recommendations per query (default: engine top_k).
      clock: injectable clock (DESIGN.md §13) for the *real* dispatch-cost
        measurement; default :class:`~repro.obs.clock.MonotonicClock`, tests
        pass :class:`~repro.obs.clock.FakeClock`.  (The latency math itself
        runs on the virtual arrival clock regardless.)
      registry: metrics registry fed with per-tenant offered/admitted/shed
        counters and latency histograms; default a private
        :class:`~repro.obs.metrics.Registry` so concurrent servers never
        share fair-shedding accounting.  CLIs pass the process-wide one.
    """

    def __init__(self, engine, *, latency_slo_ms: float | None = None,
                 batch: int = 8, max_wait_ms: float = 5.0,
                 cache_size: int = 256, fair_shedding: bool = True,
                 controller=None, dispatch_cost_fn=None,
                 top_k: int | None = None, clock=None,
                 registry: Registry | None = None):
        self.engine = engine
        self.latency_slo_s = (None if latency_slo_ms is None
                              else float(latency_slo_ms) / 1e3)
        self.batch = max(int(batch), 1)
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.clock = clock if clock is not None else MonotonicClock()
        self.metrics = registry if registry is not None else Registry()
        self.cache = ResultCache(cache_size, registry=self.metrics)
        self.fair_shedding = fair_shedding
        self.controller = (controller if controller is not None
                           else getattr(engine, "controller", None))
        self.dispatch_cost_fn = dispatch_cost_fn
        self.top_k = top_k
        self.busy_until = 0.0
        self.outcomes: list[QueryOutcome] = []
        self.dispatches = 0
        self._queue: list[_Pending] = []
        self._seq = 0
        self._tenants: list[str] = []    # insertion-ordered active tenants

    # -- work accounting (same ops basis as the engine, DESIGN.md §10) ---------

    def _per_query_work(self, state) -> float:
        eng = self.engine
        n_rules = len(state)
        k = max(min(eng.top_k if self.top_k is None else self.top_k,
                    n_rules), 0)
        kf = (min(k * eng.overfetch, n_rules)
              if eng.dedup_consequents else k)
        return float(n_rules) * state.W + 8.0 * kf * XFER_OPS_PER_BYTE

    # -- ingress ---------------------------------------------------------------

    def submit(self, basket, t_arrival: float,
               tenant: str = DEFAULT_TENANT) -> QueryOutcome:
        """Offer one query at virtual time ``t_arrival`` (non-decreasing)."""
        self._pump(t_arrival)
        out = QueryOutcome(self._seq, tenant, float(t_arrival))
        self._seq += 1
        self.outcomes.append(out)
        self._seen(tenant)

        # 1) cache fast-path: zero latency, no device work
        version = self.engine.store.version(tenant)
        k = self.top_k if self.top_k is not None else self.engine.top_k
        hit = self.cache.get(tenant, version, basket, k)
        if hit is not None:
            out.outcome = "cached"
            out.t_done = out.t_arrival
            out.latency_s = 0.0
            out.results = hit
            self._count(tenant, "admitted")
            self.metrics.histogram("serving.latency_ms",
                                   tenant=tenant).observe(0.0)
            current_tracer().add_span(
                "serve.query", out.t_arrival, out.t_arrival, tid="queries",
                tenant=tenant, outcome="cached", seq=out.seq)
            return out

        # 2) SLO admission against predicted sojourn
        dec = None
        if self.latency_slo_s is not None and self.controller is not None:
            state = self.engine.store.state
            backlog = max(self.busy_until - out.t_arrival, 0.0)
            work = self._per_query_work(state) * (len(self._queue) + 1)
            admit, dec = self.controller.should_admit(
                work=work, backlog_s=backlog,
                latency_slo_s=self.latency_slo_s)
            if not admit and not self._try_displace(tenant):
                out.outcome = "shed"
                dec.measured = 0.0
                self._count(tenant, "shed")
                current_tracer().add_span(
                    "serve.query", out.t_arrival, out.t_arrival,
                    tid="queries", tenant=tenant, outcome="shed",
                    seq=out.seq)
                return out

        self._queue.append(_Pending(out, tuple(basket), dec))
        self._count(tenant, "admitted")
        if len(self._queue) >= self.batch:
            self._dispatch_group(t_arrival)
        return out

    def flush(self, now: float | None = None) -> None:
        """Drain every queued query (end of the arrival stream)."""
        while self._queue:
            t = self._queue[-1].outcome.t_arrival
            self._dispatch_group(t if now is None else max(now, t))

    # -- internals -------------------------------------------------------------

    def _seen(self, tenant: str) -> None:
        if tenant not in self._tenants:
            self._tenants.append(tenant)
        self._count(tenant, "offered")

    def _count(self, tenant: str, what: str, n: float = 1) -> None:
        self.metrics.counter(f"serving.{what}", tenant=tenant).inc(n)

    def _tenant_n(self, tenant: str, what: str) -> float:
        return self.metrics.value(f"serving.{what}", tenant=tenant)

    def _try_displace(self, tenant: str) -> bool:
        """Fair shedding: if ``tenant`` is under its fair share of admitted
        traffic, displace the newest queued query of the most over-share
        tenant (≠ this one) and admit the arrival in its place."""
        if not self.fair_shedding or not self._queue:
            return False
        active = [t for t in self._tenants if self._tenant_n(t, "offered") > 0]
        if len(active) < 2:
            return False
        admitted = {t: self._tenant_n(t, "admitted") for t in self._tenants}
        fair = sum(admitted.values()) / len(active)
        if admitted[tenant] >= fair:
            return False
        heavy = max((t for t in active if t != tenant),
                    key=lambda t: admitted[t], default=None)
        if heavy is None or admitted[heavy] <= fair:
            return False
        for i in range(len(self._queue) - 1, -1, -1):
            p = self._queue[i]
            if p.outcome.tenant == heavy:
                del self._queue[i]
                p.outcome.outcome = "shed"
                if p.decision is not None:
                    p.decision.measured = 0.0
                self._count(heavy, "admitted", -1)   # admission revoked
                self._count(heavy, "shed")
                current_tracer().add_span(
                    "serve.query", p.outcome.t_arrival,
                    p.outcome.t_arrival, tid="queries", tenant=heavy,
                    outcome="shed", displaced=True, seq=p.outcome.seq)
                return True
        return False

    def _pump(self, now: float) -> None:
        """Fire the age trigger: dispatch once the oldest queued query has
        waited ``max_wait_s`` of virtual time."""
        while self._queue and (now - self._queue[0].outcome.t_arrival
                               >= self.max_wait_s):
            ready = self._queue[0].outcome.t_arrival + self.max_wait_s
            self._dispatch_group(min(ready, now))

    def _dispatch_group(self, now: float) -> None:
        group = self._queue[:self.batch]
        del self._queue[:len(group)]
        if not group:
            return
        state = self.engine.store.state
        pairs = [(p.outcome.tenant, p.basket) for p in group]
        versions = {p.outcome.tenant:
                    state.versions.get(p.outcome.tenant, 0) for p in group}

        t0 = self.clock.now()
        results, records = self.engine.serve([pairs], top_k=self.top_k)
        real = self.clock.now() - t0
        per_query = self._per_query_work(state)
        work = per_query * len(group)
        cost = (real if self.dispatch_cost_fn is None
                else float(self.dispatch_cost_fn(len(group), work)))

        start = max(now, self.busy_until)
        done = start + cost
        self.busy_until = done
        idx = self.dispatches
        self.dispatches += 1

        # scripted runs calibrate from the scripted cost; real runs leave
        # calibration to the engine's own controller hook (no double counts)
        if self.controller is not None and (
                self.dispatch_cost_fn is not None
                or getattr(self.engine, "controller", None) is None):
            self.controller.observe_serve(per_query, len(group), cost)

        tracer = current_tracer()
        tracer.add_span("serve.dispatch", start, done, tid="device",
                        dispatch=idx, n_queries=len(group), cost_s=cost)
        for p, recs in zip(group, results[0]):
            out = p.outcome
            out.outcome = "served"
            out.t_done = done
            out.latency_s = done - out.t_arrival
            out.dispatch_idx = idx
            out.n_fused = len(group)
            out.results = recs
            if p.decision is not None:
                p.decision.measured = out.latency_s
            self.metrics.histogram(
                "serving.latency_ms",
                tenant=out.tenant).observe(out.latency_s * 1e3)
            tracer.add_span(
                "serve.query", out.t_arrival, done, tid="queries",
                tenant=out.tenant, outcome="served", seq=out.seq,
                queue_wait_ms=(start - out.t_arrival) * 1e3,
                dispatch=idx, n_fused=len(group))
            k = self.top_k if self.top_k is not None else self.engine.top_k
            self.cache.put(out.tenant, versions[out.tenant], p.basket, k,
                           recs)

    # -- reporting -------------------------------------------------------------

    def summary(self) -> dict:
        from .common import outcome_summary
        s = outcome_summary(self.outcomes)
        s["dispatches"] = self.dispatches
        s["cache"] = {"hits": self.cache.hits, "misses": self.cache.misses,
                      "entries": len(self.cache)}
        # derived headline gauges for the metrics snapshot (DESIGN.md §13)
        answered = s["served"] + s["cached"]
        self.metrics.gauge("serving.shed_rate").set(s["shed_rate"])
        self.metrics.gauge("serving.cache_hit_rate").set(s["cache_hit_rate"])
        self.metrics.gauge("serving.qps").set(
            answered / max(self.busy_until, 1e-9))
        return s
