"""RuleStore: a tenant registry of versioned RuleSets packed into one
device-resident arena (DESIGN.md §12).

Production recommendation traffic is many catalogs/regions — *tenants* — not
one rule table.  Running one :class:`~repro.serving.rules_engine.RuleServeEngine`
per tenant would fragment the query stream into per-tenant micro-batches and
throw away exactly the dispatch-fusion win §7 built; instead all tenants'
rules live in **one packed arena** (row-concatenated ``(R_total, W)`` bitmask
arrays plus per-tenant row offsets and a tenant-id column) so a single fused
``rule_match`` dispatch scores a mixed-tenant query batch.

**Tenant isolation is a bitset trick, not a new kernel.**  Each tenant gets
one *tag bit* — an extra item id past the shared catalog (item
``n_items_base + slot``).  Every rule antecedent in the arena carries its
tenant's tag bit, and every packed query basket carries exactly its own
tenant's tag bit, so the existing word-parallel containment test
``ante ⊆ basket`` can only fire for same-tenant rules: a foreign rule's tag
bit is never present in the basket.  The test is unchanged, which means all
four impl families (jnp / pallas / matmul / matmul_pallas) serve mixed-tenant
batches bit-identically to per-tenant engines — property-tested in
``tests/test_rule_store.py``.  Consequent masks carry no tag bits, so the
novelty filter and host decode are untouched.  A single-tenant store skips
the tag bits entirely and is byte-identical to the PR 5 layout (zero-overhead
generalization).

**Atomic versioned swaps** generalize the PR 5 ``_RuleState`` reference swap:
everything derived from the registry — device arrays, float64 metric columns,
offsets, the per-shape jit cache — is bundled into one immutable
:class:`ArenaState`, rebuilt on :meth:`RuleStore.swap_rules` and published
with a single reference assignment.  A serve call captures the state once, so
in-flight mixed-tenant queries never observe a torn table; each tenant's
version counter keys the §12 result cache, so a swap invalidates that
tenant's cached answers atomically and leaves every other tenant's intact.
Unchanged tenants' packed blocks are reused across rebuilds (cached per
entry, keyed by arena geometry), so a swap costs O(changed tenant) host work
plus one concatenate.
"""

from __future__ import annotations

import threading

import jax.numpy as jnp
import numpy as np

from repro.core.bitset import WORD_BITS, n_words, unpack_itemsets
from repro.core.rules import RuleSet

DEFAULT_TENANT = "default"


def _pack_block(rules: RuleSet, W: int, tag: int | None) -> tuple:
    """One tenant's (ante, cons) masks widened to arena width ``W`` words,
    with the tenant tag bit OR-ed into every antecedent (``tag`` is the
    arena-wide item id of the tenant's tag bit; None = untagged arena)."""
    R = len(rules)
    w_t = rules.ante_masks.shape[1] if R else 0
    ante = np.zeros((R, W), np.uint32)
    cons = np.zeros((R, W), np.uint32)
    if R:
        ante[:, :w_t] = rules.ante_masks
        cons[:, :w_t] = rules.cons_masks
        if tag is not None:
            ante[:, tag // WORD_BITS] |= np.uint32(1 << (tag % WORD_BITS))
    return ante, cons


class ArenaState:
    """Immutable snapshot of the whole registry — the unit of atomic publish.

    Provides everything a serve dispatch needs: the device-resident packed
    arrays, per-tenant offsets/versions, exact float64 metric columns in
    arena row order, the lazy consequent-decode cache, and the per-shape jit
    cache (fresh per state, so a swap can never serve stale compiled
    closures over old arrays).
    """

    def __init__(self, entries: dict):
        self.tenants = tuple(entries)
        self.tagged = len(self.tenants) > 1
        self.n_items_base = max(
            [e.rules.n_items for e in entries.values()], default=1)
        self.n_items = self.n_items_base + (
            len(self.tenants) if self.tagged else 0)
        self.W = n_words(max(self.n_items, 1))
        self.versions = {t: e.version for t, e in entries.items()}
        self.rulesets = {t: e.rules for t, e in entries.items()}
        self.slots = {t: (self.n_items_base + i if self.tagged else None)
                      for i, t in enumerate(self.tenants)}

        antes, conss, scores, confs, lifts, tids = [], [], [], [], [], []
        self.offsets: dict[str, int] = {}
        off = 0
        for i, (t, e) in enumerate(entries.items()):
            a, c = e.packed(self.W, self.slots[t])
            conf64, lift64 = e.metrics()
            self.offsets[t] = off
            off += len(e.rules)
            antes.append(a)
            conss.append(c)
            scores.append(e.rules.score)
            confs.append(conf64)
            lifts.append(lift64)
            tids.append(np.full(len(e.rules), i, np.int32))
        z = np.zeros((0, self.W), np.uint32)
        self.ante_masks = np.concatenate(antes, axis=0) if antes else z
        self.cons_masks = np.concatenate(conss, axis=0) if conss else z
        self.tenant_ids = (np.concatenate(tids)
                           if tids else np.zeros(0, np.int32))
        self.conf64 = (np.concatenate(confs)
                       if confs else np.zeros(0, np.float64))
        self.lift64 = (np.concatenate(lifts)
                       if lifts else np.zeros(0, np.float64))
        self.d_ante = jnp.asarray(self.ante_masks)
        self.d_cons = jnp.asarray(self.cons_masks)
        self.d_scores = jnp.asarray(
            np.concatenate(scores) if scores
            else np.zeros(0, np.float32), jnp.float32)
        self.cons_cache: dict[int, tuple] = {}
        self.jitted: dict = {}

    def __len__(self) -> int:
        return self.ante_masks.shape[0]

    @property
    def rules(self) -> RuleSet:
        """The sole tenant's RuleSet (single-tenant compatibility surface)."""
        if len(self.tenants) != 1:
            raise ValueError(
                f"store holds {len(self.tenants)} tenants; address one by "
                f"name instead of .rules")
        return self.rulesets[self.tenants[0]]

    def tenant_of(self, r: int) -> str:
        return self.tenants[int(self.tenant_ids[r])]

    def cons_tuple(self, r: int) -> tuple:
        """Lazy host decode of one rule's consequent (tag bits never appear
        in consequent masks, so arena rows decode like tenant-local ones)."""
        if r not in self.cons_cache:
            self.cons_cache[r] = unpack_itemsets(
                self.cons_masks[r:r + 1])[0]
        return self.cons_cache[r]

    def pack(self, pairs) -> np.ndarray:
        """(tenant, basket) pairs → (Q, W) uint32 arena bitsets.

        Items are clipped to the query's own tenant catalog (ids ≥ that
        tenant's ``n_items`` are ignored, exactly as a per-tenant engine
        would), then the tenant's tag bit is OR-ed in so only its rules can
        fire.  Unknown tenants raise — admission happens upstream.
        """
        out = np.zeros((len(pairs), self.W), np.uint32)
        for q, (tenant, basket) in enumerate(pairs):
            if tenant not in self.rulesets:
                raise KeyError(f"unknown tenant {tenant!r}; "
                               f"registered: {list(self.tenants)}")
            n_it = self.rulesets[tenant].n_items
            row = out[q]
            for it in basket:
                if 0 <= it < n_it:
                    row[it // WORD_BITS] |= np.uint32(1 << (it % WORD_BITS))
            slot = self.slots[tenant]
            if slot is not None:
                row[slot // WORD_BITS] |= np.uint32(1 << (slot % WORD_BITS))
        return out


class _Entry:
    """One tenant's registry slot: RuleSet, version, and per-geometry caches
    (packed blocks + metric columns survive *other* tenants' swaps)."""

    def __init__(self, rules: RuleSet, version: int = 0):
        self.rules = rules
        self.version = version
        self._packed: dict = {}
        self._metrics = None

    def packed(self, W: int, tag: int | None):
        key = (W, tag)
        if key not in self._packed:
            self._packed = {key: _pack_block(self.rules, W, tag)}
        return self._packed[key]

    def metrics(self):
        if self._metrics is None:
            _, conf64, lift64, _ = self.rules.exact_metrics()
            self._metrics = (conf64, lift64)
        return self._metrics


class RuleStore:
    """The tenant registry.  Mutations (register/swap) rebuild an
    :class:`ArenaState` and publish it atomically; reads just take
    :attr:`state` — no lock on the serve path.

    Args:
      rules: single-tenant convenience — registers one RuleSet under
        :data:`DEFAULT_TENANT`.
      tenants: ``{tenant_name: RuleSet}`` initial registry (insertion order
        fixes arena row order and tag-slot assignment).
    """

    def __init__(self, rules: RuleSet | None = None, *,
                 tenants: dict | None = None):
        if (rules is None) == (tenants is None):
            raise ValueError("pass exactly one of rules= or tenants=")
        self._lock = threading.Lock()
        init = tenants if tenants is not None else {DEFAULT_TENANT: rules}
        self._entries = {t: _Entry(rs) for t, rs in init.items()}
        self._state = ArenaState(self._entries)

    @property
    def state(self) -> ArenaState:
        return self._state

    @property
    def tenants(self) -> tuple:
        return self._state.tenants

    def version(self, tenant: str) -> int:
        return self._state.versions[tenant]

    def ruleset(self, tenant: str = DEFAULT_TENANT) -> RuleSet:
        return self._state.rulesets[tenant]

    def swap_rules(self, tenant: str, rules: RuleSet,
                   warm=None) -> ArenaState:
        """Atomically replace (or register) one tenant's RuleSet.

        The complete successor :class:`ArenaState` is built first —
        ``warm(state)``, when given, pre-compiles dispatch shapes against it
        so the first post-swap dispatch pays no compile cost — and only then
        published with one reference assignment.  Readers that captured the
        old state keep a complete old table; the tenant's version counter
        bumps, which is what invalidates its cached results.
        """
        with self._lock:
            prev = self._entries.get(tenant)
            entry = _Entry(rules, (prev.version + 1) if prev else 0)
            entries = dict(self._entries)
            entries[tenant] = entry
            state = ArenaState(entries)
            if warm is not None:
                warm(state)
            self._entries = entries
            self._state = state
        return state
