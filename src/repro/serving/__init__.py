from .admission import (OpenLoopServer, QueryOutcome, ResultCache,
                        basket_key)
from .common import outcome_summary
from .engine import ServeEngine, ServePhaseRecord
from .rule_store import DEFAULT_TENANT, ArenaState, RuleStore
from .rules_engine import (Recommendation, RuleServeEngine, RuleServeRecord,
                           RULE_IMPLS)

__all__ = ["ServeEngine", "ServePhaseRecord",
           "Recommendation", "RuleServeEngine", "RuleServeRecord",
           "RULE_IMPLS",
           "RuleStore", "ArenaState", "DEFAULT_TENANT",
           "OpenLoopServer", "QueryOutcome", "ResultCache", "basket_key",
           "outcome_summary"]
