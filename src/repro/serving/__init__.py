from .engine import ServeEngine, ServePhaseRecord

__all__ = ["ServeEngine", "ServePhaseRecord"]
