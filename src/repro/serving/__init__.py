from .engine import ServeEngine, ServePhaseRecord
from .rules_engine import (Recommendation, RuleServeEngine, RuleServeRecord,
                           RULE_IMPLS)

__all__ = ["ServeEngine", "ServePhaseRecord",
           "Recommendation", "RuleServeEngine", "RuleServeRecord",
           "RULE_IMPLS"]
