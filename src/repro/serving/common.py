"""Shared serving-layer helpers (DESIGN.md §7/§8).

Query bit-packing, pow2 query-shape bucketing and the per-query latency
roll-up used to be private to ``serving/rules_engine.py`` and re-derived by
every CLI/benchmark that reported percentiles; the streaming subsystem adds a
third consumer, so they live here once.  ``ServeEngine`` (LM decode) shares
the policy machinery through ``core/policy.py`` and the shape-bucket idea
through :func:`bucket_rows`.
"""

from __future__ import annotations

import numpy as np

from repro.core.bitset import pack_itemsets
from repro.kernels.autotune import _bucket

MIN_QUERY_BUCKET = 8


def bucket_rows(n: int, floor: int = MIN_QUERY_BUCKET) -> int:
    """Power-of-two row bucket ≥ n — a handful of compiled query shapes.
    Same rounding as the autotuner's shape buckets, floored for tiny batches."""
    return max(floor, _bucket(n))


def pack_baskets(baskets, n_items: int) -> np.ndarray:
    """Item-id baskets → (Q, W) uint32 bitsets; unknown ids are ignored."""
    clean = [[i for i in b if 0 <= i < n_items] for b in baskets]
    return pack_itemsets(clean, n_items)


def latency_ms(records) -> np.ndarray:
    """Per-query dispatch latencies in ms from a serve-record trace.

    Each record's elapsed time is attributed to every query it answered
    (empty dispatches count once), so percentiles weight by queries served.
    """
    if not records:
        return np.zeros(0, np.float64)
    return np.repeat([r.elapsed * 1e3 for r in records],
                     [max(r.n_queries, 1) for r in records])


def latency_percentiles(records) -> dict:
    """{"p50_ms", "p99_ms"} of the per-query dispatch latency."""
    lat = latency_ms(records)
    if lat.size == 0:
        return {"p50_ms": 0.0, "p99_ms": 0.0}
    return {"p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99))}


def outcome_summary(outcomes) -> dict:
    """Roll up open-loop :class:`~repro.serving.admission.QueryOutcome` rows.

    Answered-query latency percentiles (served + cached — cache hits are real
    answers at zero latency; shed queries got no answer so they don't get a
    latency, they get a shed rate), overall shed/cache rates, and the
    per-tenant admitted/shed split fairness assertions read.
    """
    n = len(outcomes)
    served = [o for o in outcomes if o.outcome == "served"]
    cached = [o for o in outcomes if o.outcome == "cached"]
    shed = [o for o in outcomes if o.outcome == "shed"]
    lat = np.asarray([o.latency_s * 1e3 for o in served + cached
                      if o.latency_s is not None], np.float64)
    tenants: dict = {}
    for o in outcomes:
        row = tenants.setdefault(o.tenant, {"offered": 0, "answered": 0,
                                            "shed": 0})
        row["offered"] += 1
        row["shed" if o.outcome == "shed" else "answered"] += 1
    return {
        "n_queries": n,
        "served": len(served),
        "cached": len(cached),
        "shed": len(shed),
        "shed_rate": len(shed) / n if n else 0.0,
        "cache_hit_rate": len(cached) / n if n else 0.0,
        "p50_ms": float(np.percentile(lat, 50)) if lat.size else 0.0,
        "p99_ms": float(np.percentile(lat, 99)) if lat.size else 0.0,
        "tenants": tenants,
    }
