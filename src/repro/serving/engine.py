"""Serving engine: batched KV-cache decoding with **paper-policy dispatch fusion**.

The isomorphism to the paper (DESIGN.md §3):

  Apriori pass              ≙ one decode step for the whole batch
  MapReduce job overhead    ≙ host sync + dispatch + collective setup per step
  multi-pass phase          ≙ ``lax.scan`` over npass decode steps in ONE dispatch
  candidate count |C|       ≙ active (unfinished) requests × passes
  pruning step              ≙ per-step in-graph EOS masking of finished rows
  skipped pruning           ≙ fused steps emit raw tokens; finished rows keep
                              "generating" and the phase-end host check trims them
  un-pruned candidates      ≙ tokens emitted past EOS — wasted work that cannot
                              corrupt output (trimmed like infrequent candidates)

Seven paper algorithms, same Policy objects as the mining drivers: spc (1 step
per dispatch), fpc (fixed), dpc, vfpc, etdpc and the optimized_* variants —
plus ``measured``, which fuses from the calibrated cost model under an
optional latency budget (DESIGN.md §9).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.core.policy import ALGORITHMS, PhaseStats
from repro.models.model import Model, ShardCtx


@dataclasses.dataclass
class ServePhaseRecord:
    phase_idx: int
    npass: int
    active_before: int
    tokens_emitted: int
    wasted_tokens: int          # emitted after a row's EOS (un-pruned analogue)
    elapsed: float


class ServeEngine:
    def __init__(self, model: Model, params, cache_len: int,
                 algorithm: str = "optimized_vfpc", mesh=None, rules=None,
                 policy_kwargs: dict | None = None, max_npass: int = 32,
                 pad_id: int = 0, pipeline_depth: int = 1,
                 latency_budget_ms: float | None = None, controller=None):
        """``pipeline_depth > 1`` (optimized engines only): keep that many
        fused phases in flight and read results one phase behind — the host
        EOS check ("pruning") lags the dispatch stream, trading a few more
        post-EOS tokens for zero host-sync bubbles between phases.

        ``algorithm="measured"`` fuses decode steps from the calibrated cost
        model (DESIGN.md §9): the widest phase whose predicted dispatch time
        fits ``latency_budget_ms`` (maximal fusion when no budget is set).
        ``controller`` shares a :class:`repro.costmodel.CostController`; any
        engine given one calibrates it per dispatch, whatever its policy."""
        self.model = model
        self.params = params
        self.cache_len = cache_len
        self.mesh, self.rules = mesh, rules
        self.ctx = ShardCtx(mesh, rules)
        policy_cls, self.optimized = ALGORITHMS[algorithm]
        self.algorithm = algorithm
        self.latency_budget_s = (None if latency_budget_ms is None
                                 else float(latency_budget_ms) / 1e3)
        if algorithm == "measured":
            if controller is None:
                from repro.costmodel import CostController
                controller = CostController()
            self.policy = None
        else:
            self.policy = policy_cls(**(policy_kwargs or {}))
        self.controller = controller
        self.max_npass = max_npass
        self.pad_id = pad_id
        self.pipeline_depth = pipeline_depth if self.optimized else 1
        self._multi = {}
        self._prefill = jax.jit(
            lambda p, b, lp: model.prefill(p, b, cache_len, self.ctx, last_pos=lp))
        self.records: list[ServePhaseRecord] = []

    # -- jitted phase ----------------------------------------------------------

    def _multi_step(self, npass: int, masked: bool):
        """One fused dispatch of ``npass`` greedy decode steps."""
        key = (npass, masked)
        if key in self._multi:
            return self._multi[key]
        model, ctx, pad_id = self.model, self.ctx, self.pad_id

        def fn(params, caches, token, pos, eos_seen, eos_id):
            def step(carry, _):
                caches, token, pos, eos_seen = carry
                logits, caches = model.decode_step(params, caches, token, pos, ctx)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                if masked:  # "pruning": per-step EOS bookkeeping in-graph
                    eos_seen = eos_seen | (token[:, 0] == eos_id)
                    nxt = jnp.where(eos_seen, pad_id, nxt)
                return (caches, nxt[:, None], pos + 1, eos_seen), nxt

            (caches, token, pos, eos_seen), toks = jax.lax.scan(
                step, (caches, token, pos, eos_seen), None, length=npass)
            return caches, token, pos, eos_seen, toks  # toks: (npass, B)

        self._multi[key] = jax.jit(fn, donate_argnums=(1,))
        return self._multi[key]

    # -- host driver -------------------------------------------------------------

    def generate(self, prompts: np.ndarray, prompt_lens: np.ndarray | None = None,
                 max_new_tokens: int = 64, eos_id: int = -1,
                 extra_batch: dict | None = None):
        """Greedy-generate for a right-padded prompt batch.

        Returns (tokens (B, max_new_tokens) with pad after EOS, records).
        """
        B, S = prompts.shape
        if prompt_lens is None:
            prompt_lens = np.full((B,), S, np.int32)
        last_pos = jnp.asarray(prompt_lens - 1, jnp.int32)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra_batch:
            batch.update(extra_batch)

        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, batch, last_pos)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        prefill_time = time.perf_counter() - t0

        out = np.full((B, max_new_tokens), self.pad_id, np.int32)
        out[:, 0] = np.asarray(first)
        eos_seen_host = (out[:, 0] == eos_id)
        produced = 1
        token = first[:, None]
        pos = jnp.asarray(prompt_lens, jnp.int32)
        eos_seen = jnp.asarray(eos_seen_host)
        history: list[PhaseStats] = []
        self.records = []
        phase_idx = 0
        history.append(PhaseStats(B, B, prefill_time))

        inflight: list = []   # (phase_idx, npass, active, toks_dev, t_issue)
        scheduled = produced  # positions dispatched (≥ produced when pipelining)

        def drain_one():
            nonlocal produced, phase_idx
            pidx, npass, active, toks_dev, t_issue = inflight.pop(0)
            toks = np.array(jax.device_get(toks_dev)).T  # (B, npass), writable
            elapsed = time.perf_counter() - t_issue
            # phase-end "support filter": trim tokens emitted after EOS
            wasted = 0
            for b in range(B):
                for j in range(npass):
                    if eos_seen_host[b]:
                        wasted += int(toks[b, j] != self.pad_id)
                        toks[b, j] = self.pad_id
                    elif toks[b, j] == eos_id:
                        out[b, produced + j] = toks[b, j]
                        eos_seen_host[b] = True
                    else:
                        out[b, produced + j] = toks[b, j]
            produced += npass
            if self.controller is not None:
                self.controller.observe_serve(float(B), npass, elapsed,
                                              kind="decode")
            history.append(PhaseStats(npass * active, active, elapsed))
            self.records.append(ServePhaseRecord(
                pidx, npass, active, npass * active, wasted, elapsed))

        while scheduled < max_new_tokens and not eos_seen_host.all():
            active = int((~eos_seen_host).sum())
            if self.policy is None:   # measured: decode-step fusion from the
                                      # cost model (ops basis: batch rows/step)
                npass = self.controller.choose_fusion(
                    work_per_unit=float(B),
                    queued=max_new_tokens - scheduled,
                    max_fuse=self.max_npass,
                    latency_budget_s=self.latency_budget_s, kind="decode")
                npass = 1 if npass is None else int(npass)
            else:
                prev = history[-1] if history else None
                prev2 = history[-2] if len(history) > 1 else None
                mode, val = self.policy.decide(prev, prev2)
                if mode == "width":
                    npass = int(val)
                else:  # budget: passes while cumulative candidates ≤ α·active
                    npass = int(np.floor(val)) + 1
            npass = max(1, min(npass, self.max_npass, max_new_tokens - scheduled))

            fn = self._multi_step(npass, masked=not self.optimized)
            t0 = time.perf_counter()
            caches, token, pos, eos_seen, toks = fn(
                self.params, caches, token, pos, eos_seen,
                jnp.int32(eos_id))
            scheduled += npass
            inflight.append((phase_idx, npass, active, toks, t0))
            phase_idx += 1
            # pipelining: keep up to `pipeline_depth` phases in flight; the
            # EOS check lags behind the dispatch stream
            while len(inflight) >= self.pipeline_depth:
                drain_one()
                eos_seen = jnp.asarray(eos_seen_host)
        while inflight:
            drain_one()

        return out, self.records

    @property
    def dispatches(self) -> int:
        return len(self.records)
