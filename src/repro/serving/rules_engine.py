"""Batched association-rule serving — the mine → rules → serve endgame
(DESIGN.md §7).

Incoming basket queries are bit-packed into transaction bitsets (§2) and
matched against the :class:`~repro.core.rules.RuleSet`'s antecedents with the
same word-parallel ``(c & t) == c`` containment test the counting kernels use
— ``kernels/rule_match.py`` provides the Pallas variant and the blocked-jnp
oracle, block sizes autotuned via ``kernels/autotune.py`` (§5).  Each dispatch
emits the masked (Q, R) confidence·lift score matrix and reduces it with a
device-side ``lax.top_k``; only the (Q, k) winners cross back to the host.

Micro-batching: queued query batches are fused per dispatch by the same
pass-combining ``Policy`` objects the mining drivers and the LM
:class:`~repro.serving.engine.ServeEngine` share (``core/policy.py``).  The
isomorphism: one dispatch answering ``npass`` queued batches is the serving
analogue of one counting job covering ``npass`` Apriori levels — candidate
count |C| maps to rule·query pairs scored, |L| to queries answered.  The SPC
policy reproduces strict per-batch dispatch (the "unfused" benchmark arm).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitset import n_words, pack_itemsets, unpack_itemsets
from repro.core.policy import ALGORITHMS, PhaseStats
from repro.core.rules import RuleSet
from repro.kernels.autotune import DEFAULTS, _bucket, tuned_blocks
from repro.kernels.rule_match import rule_scores_jnp, rule_scores_pallas

RULE_IMPLS = ("auto", "jnp", "pallas", "pallas_interpret")

MIN_QUERY_BUCKET = 8


@dataclasses.dataclass(frozen=True)
class Recommendation:
    consequent: tuple       # item ids the rule recommends
    confidence: float       # exact float64, from the RuleSet's integer counts
    lift: float
    score: float            # float32 confidence·lift rank key (device value)


@dataclasses.dataclass
class RuleServeRecord:
    phase_idx: int
    n_batches: int          # queued query batches fused into this dispatch
    n_queries: int
    elapsed: float


def _bucket_rows(n: int, floor: int = MIN_QUERY_BUCKET) -> int:
    """Power-of-two row bucket ≥ n — a handful of compiled query shapes.
    Same rounding as the autotuner's shape buckets, floored for tiny batches."""
    return max(floor, _bucket(n))


class RuleServeEngine:
    """Answer basket queries with top-k rule consequents by confidence·lift.

    Args:
      rules: a RuleSet from ``core.rules.generate_ruleset``.
      top_k: default number of recommendations per query.
      impl: "auto" | "jnp" | "pallas" | "pallas_interpret" — the containment
        scoring path ("auto": pallas on TPU, jnp elsewhere; "pallas" off-TPU
        degrades to interpret mode, like the counting kernels).
      algorithm: pass-combining policy fusing queued query batches per
        dispatch (core/policy.py; "spc" = strict per-batch dispatch).
      max_fuse: cap on batches fused into one dispatch.
      exclude_contained: drop rules whose consequent the basket already
        contains (nothing new to recommend) — fused into the scoring kernel.
      dedup_consequents: return k *distinct* consequents per query (several
        rules can share one); the device top-k overfetches ``overfetch``×k
        rule slots and the host decode keeps each consequent's best-scoring
        hit.  False returns raw rule-level top-k.
      overfetch: rule slots fetched per requested consequent when deduping
        (clamped to the rule count; a bound, not a guarantee, when one
        consequent dominates more than that many rules).
      autotune: consult the block-size autotuner; False pins static defaults.
    """

    def __init__(self, rules: RuleSet, *, top_k: int = 5, impl: str = "auto",
                 algorithm: str = "optimized_vfpc",
                 policy_kwargs: dict | None = None, max_fuse: int = 16,
                 exclude_contained: bool = True,
                 dedup_consequents: bool = True, overfetch: int = 8,
                 autotune: bool = True):
        if impl not in RULE_IMPLS:
            raise ValueError(f"unknown impl {impl!r}; options: {RULE_IMPLS}")
        if algorithm not in ALGORITHMS:
            raise ValueError(
                f"unknown algorithm {algorithm!r}; options: {sorted(ALGORITHMS)}")
        backend = jax.default_backend()
        if impl == "auto":
            impl = "pallas" if backend == "tpu" else "jnp"
        self._interpret = (impl == "pallas_interpret"
                           or (impl == "pallas" and backend != "tpu"))
        self.impl = "pallas" if impl.startswith("pallas") else "jnp"
        self.rules = rules
        self.top_k = top_k
        self.max_fuse = max_fuse
        self.exclude_contained = exclude_contained
        self.dedup_consequents = dedup_consequents
        self.overfetch = max(int(overfetch), 1)
        self.autotune = autotune
        policy_cls, _ = ALGORITHMS[algorithm]
        self.algorithm = algorithm
        self.policy = policy_cls(**(policy_kwargs or {}))

        self._W = n_words(rules.n_items)
        self._d_ante = jnp.asarray(rules.ante_masks)
        self._d_cons = jnp.asarray(rules.cons_masks)
        self._d_scores = jnp.asarray(rules.score, jnp.float32)
        # host decode: exact float64 metrics (vectorized) + a lazy per-index
        # consequent-tuple cache — only rules top_k actually surfaces pay the
        # host bit-walk, never all R of them
        self._cons_cache: dict[int, tuple] = {}
        _, self._conf64, self._lift64, _ = rules.exact_metrics()

        self.records: list[RuleServeRecord] = []
        self._jitted: dict = {}

    @property
    def n_rules(self) -> int:
        return len(self.rules)

    @property
    def dispatches(self) -> int:
        return len(self.records)

    # -- jitted dispatch -------------------------------------------------------

    def _blocks(self, impl_key: str, Qp: int) -> dict:
        if not self.autotune:
            return dict(DEFAULTS[impl_key])
        return tuned_blocks(impl_key, C=max(self.n_rules, 1), T=Qp, W=self._W)

    def _fn(self, Qp: int, k: int):
        key = (Qp, k)
        if key in self._jitted:
            return self._jitted[key]
        ante, cons, scores = self._d_ante, self._d_cons, self._d_scores
        excl = self.exclude_contained
        if self.impl == "jnp":
            blocks = self._blocks("rules_jnp", Qp)
            qb = min(blocks["q_block"], Qp)

            def fn(baskets):
                s = rule_scores_jnp(ante, cons, scores, baskets,
                                    q_block=qb, exclude_contained=excl)
                return jax.lax.top_k(s, k)
        else:
            impl_key = ("rules_pallas_interpret" if self._interpret
                        else "rules_pallas")
            blocks = self._blocks(impl_key, Qp)
            interpret = self._interpret

            def fn(baskets):
                s = rule_scores_pallas(ante, cons, scores, baskets,
                                       bq=blocks["bq"], br=blocks["br"],
                                       exclude_contained=excl,
                                       interpret=interpret)
                return jax.lax.top_k(s, k)
        self._jitted[key] = jax.jit(fn)
        return self._jitted[key]

    def _dispatch(self, packed: np.ndarray, k: int):
        """(Q, W) packed baskets → host (Q, k) score values + rule indices."""
        Q = packed.shape[0]
        Qp = _bucket_rows(Q)
        if Qp != Q:
            packed = np.concatenate(
                [packed, np.zeros((Qp - Q, self._W), np.uint32)], axis=0)
        vals, idx = self._fn(Qp, k)(jnp.asarray(packed))
        return np.asarray(vals)[:Q], np.asarray(idx)[:Q]

    def warmup(self, max_queries: int, top_k: int | None = None):
        """Pre-compile every pow2 query bucket up to ``max_queries`` (and run
        the autotuner) so no dispatch in the serving loop pays compile cost."""
        k = max(min(self.top_k if top_k is None else top_k, self.n_rules), 0)
        if k == 0:
            return
        kf = min(k * self.overfetch, self.n_rules) if self.dedup_consequents else k
        b = MIN_QUERY_BUCKET
        while True:
            self._dispatch(np.zeros((b, self._W), np.uint32), kf)
            if b >= max_queries:
                break
            b *= 2

    # -- host driver -----------------------------------------------------------

    def _pack(self, baskets) -> np.ndarray:
        """Item-id baskets → (Q, W) uint32 bitsets; unknown ids are ignored."""
        n = self.rules.n_items
        clean = [[i for i in b if 0 <= i < n] for b in baskets]
        return pack_itemsets(clean, n)

    def _cons_tuple(self, r: int) -> tuple:
        if r not in self._cons_cache:
            self._cons_cache[r] = unpack_itemsets(
                self.rules.cons_masks[r:r + 1])[0]
        return self._cons_cache[r]

    def _decode(self, vals: np.ndarray, idx: np.ndarray, k: int):
        dedup = self.dedup_consequents
        out = []
        for q in range(vals.shape[0]):
            recs = []
            seen: set = set()
            for j in range(vals.shape[1]):
                # -inf is the kernel's no-match sentinel; +inf is a legal score
                # (legacy missing-consequent lift) and must decode normally
                if np.isneginf(vals[q, j]) or len(recs) >= k:
                    break
                r = int(idx[q, j])
                cons = self._cons_tuple(r)
                if dedup:
                    if cons in seen:
                        continue    # a lower-scored rule for the same consequent
                    seen.add(cons)
                recs.append(Recommendation(
                    cons, float(self._conf64[r]), float(self._lift64[r]),
                    float(vals[q, j])))
            out.append(recs)
        return out

    def serve(self, batches, top_k: int | None = None):
        """Answer a queue of basket batches with policy-fused dispatches.

        Args:
          batches: sequence of batches; each batch is a list of baskets
            (iterables of item ids).
          top_k: recommendations per query (default: engine top_k).

        Returns ``(results, records)`` — ``results[b][q]`` is the list of
        :class:`Recommendation` for basket ``q`` of batch ``b``, and
        ``records`` the per-dispatch :class:`RuleServeRecord` trace (also kept
        on ``self.records``).
        """
        k = max(min(self.top_k if top_k is None else top_k, self.n_rules), 0)
        batches = list(batches)
        results: list = []
        records: list[RuleServeRecord] = []
        history: list[PhaseStats] = []
        if self.n_rules == 0 or k == 0:       # no rules: everything is empty
            results = [[[] for _ in b] for b in batches]
            self.records = records
            return results, records

        i, phase_idx = 0, 0
        while i < len(batches):
            prev = history[-1] if history else None
            prev2 = history[-2] if len(history) > 1 else None
            mode, val = self.policy.decide(prev, prev2)
            if mode == "width":
                nfuse = int(val)
            else:  # budget_alpha: fuse ⌊α⌋ queued batches (α=1 ⇒ per-batch,
                   # matching the drivers' "no widening" baseline semantics)
                nfuse = int(np.floor(val))
            nfuse = max(1, min(nfuse, self.max_fuse, len(batches) - i))
            group = batches[i:i + nfuse]
            sizes = [len(b) for b in group]
            flat = [basket for batch in group for basket in batch]

            t0 = time.perf_counter()
            if flat:
                kf = (min(k * self.overfetch, self.n_rules)
                      if self.dedup_consequents else k)
                vals, idx = self._dispatch(self._pack(flat), kf)
                decoded = self._decode(vals, idx, k)
            else:
                decoded = []
            elapsed = time.perf_counter() - t0

            off = 0
            for sz in sizes:
                results.append(decoded[off:off + sz])
                off += sz
            n_q = len(flat)
            history.append(PhaseStats(self.n_rules * max(n_q, 1),
                                      max(n_q, 1), elapsed))
            records.append(RuleServeRecord(phase_idx, nfuse, n_q, elapsed))
            i += nfuse
            phase_idx += 1
        self.records = records
        return results, records

    def query(self, baskets, top_k: int | None = None):
        """Single-batch convenience: recommendations for one list of baskets."""
        results, _ = self.serve([list(baskets)], top_k=top_k)
        return results[0]
