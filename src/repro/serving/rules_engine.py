"""Batched association-rule serving — the mine → rules → serve endgame
(DESIGN.md §7, multi-tenant since §12).

Incoming basket queries are bit-packed into transaction bitsets (§2) and
matched against rule antecedents with the same word-parallel ``(c & t) == c``
containment test the counting kernels use — ``kernels/rule_match.py`` provides
the Pallas variant and the blocked-jnp oracle, block sizes autotuned via
``kernels/autotune.py`` (§5).  Each dispatch emits the masked (Q, R)
confidence·lift score matrix and reduces it with a device-side
``lax.top_k``; only the (Q, k) winners cross back to the host.

Micro-batching: queued query batches are fused per dispatch by the same
pass-combining ``Policy`` objects the mining drivers and the LM
:class:`~repro.serving.engine.ServeEngine` share (``core/policy.py``).  The
isomorphism: one dispatch answering ``npass`` queued batches is the serving
analogue of one counting job covering ``npass`` Apriori levels — candidate
count |C| maps to rule·query pairs scored, |L| to queries answered.  The SPC
policy reproduces strict per-batch dispatch (the "unfused" benchmark arm).

Multi-tenant serving (DESIGN.md §12): the engine sits on a
:class:`~repro.serving.rule_store.RuleStore` — a tenant registry of versioned
RuleSets packed into one device-resident arena — so one fused dispatch serves
*mixed-tenant* query batches; per-tenant tag bits in the packed baskets keep
isolation inside the unchanged containment test.  Constructing the engine
from a bare RuleSet wraps it in a single-tenant store (byte-identical to the
PR 5 layout), and queries may be bare baskets (default tenant) or
``(tenant, basket)`` pairs.

Live rule refresh (DESIGN.md §8/§12): everything derived from the registry —
device arrays, float64 metric columns, the per-shape jit cache — is bundled
into one immutable :class:`~repro.serving.rule_store.ArenaState`, and
:meth:`RuleServeEngine.swap_rules` replaces the whole bundle with a single
reference assignment.  A serve call captures the state once, so in-flight
queries never observe a half-swapped ("torn") rule table; the next call sees
the fresh rules.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import ALGORITHMS, PhaseStats
from repro.core.rules import RuleSet
from repro.obs.trace import current_tracer
from repro.kernels.autotune import DEFAULTS, tuned_blocks, tuned_plan
from repro.kernels.rule_match import (rule_scores_jnp, rule_scores_matmul,
                                      rule_scores_matmul_pallas,
                                      rule_scores_pallas)
from repro.roofline import XFER_OPS_PER_BYTE

from .common import MIN_QUERY_BUCKET, bucket_rows
from .rule_store import DEFAULT_TENANT, ArenaState, RuleStore

RULE_IMPLS = ("auto", "jnp", "pallas", "pallas_interpret", "matmul",
              "matmul_pallas", "matmul_pallas_interpret")


@dataclasses.dataclass(frozen=True)
class Recommendation:
    consequent: tuple       # item ids the rule recommends
    confidence: float       # exact float64, from the RuleSet's integer counts
    lift: float
    score: float            # float32 confidence·lift rank key (device value)


@dataclasses.dataclass
class RuleServeRecord:
    phase_idx: int
    n_batches: int          # queued query batches fused into this dispatch
    n_queries: int
    elapsed: float


def as_tenant_pairs(batch, tenant: str | None = None) -> list:
    """Normalize one query batch to ``(tenant, basket)`` pairs.

    ``tenant`` (when given) applies to every query; otherwise a 2-tuple whose
    first element is a str is already a pair and a bare basket gets
    :data:`DEFAULT_TENANT`.
    """
    if tenant is not None:
        return [(tenant, basket) for basket in batch]
    out = []
    for q in batch:
        if (isinstance(q, tuple) and len(q) == 2
                and isinstance(q[0], str)):
            out.append(q)
        else:
            out.append((DEFAULT_TENANT, q))
    return out


class RuleServeEngine:
    """Answer basket queries with top-k rule consequents by confidence·lift.

    Args:
      rules: a RuleSet from ``core.rules.generate_ruleset`` (wrapped in a
        single-tenant :class:`RuleStore`), or a RuleStore for multi-tenant
        serving through the packed arena (DESIGN.md §12).
      top_k: default number of recommendations per query.
      impl: one of ``RULE_IMPLS`` — the containment scoring path: popcount
        ("jnp"/"pallas") or bit-plane matmul ("matmul"/"matmul_pallas",
        DESIGN.md §10) forms; "auto" resolves per dispatch shape to the
        cross-family autotune plan winner when autotune is on (static
        fallback: pallas on TPU, matmul on GPU, jnp elsewhere); "*pallas"
        off-TPU degrades to interpret mode, like the counting kernels.
      algorithm: pass-combining policy fusing queued query batches per
        dispatch (core/policy.py; "spc" = strict per-batch dispatch).
      max_fuse: cap on batches fused into one dispatch.
      exclude_contained: drop rules whose consequent the basket already
        contains (nothing new to recommend) — fused into the scoring kernel.
      dedup_consequents: return k *distinct* consequents per query (several
        rules can share one); the device top-k overfetches ``overfetch``×k
        rule slots and the host decode keeps each consequent's best-scoring
        hit.  False returns raw rule-level top-k.
      overfetch: rule slots fetched per requested consequent when deduping
        (clamped to the rule count; a bound, not a guarantee, when one
        consequent dominates more than that many rules).
      autotune: consult the block-size autotuner; False pins static defaults.
      latency_budget_ms: per-dispatch latency budget for the ``measured``
        algorithm — fuse the most batches whose predicted dispatch time
        stays under it (None: fuse maximally, pure throughput).
      controller: :class:`repro.costmodel.CostController` for the
        ``measured`` algorithm's fusion decisions (DESIGN.md §9); default
        shares the process-wide model.
    """

    def __init__(self, rules: RuleSet | RuleStore, *, top_k: int = 5,
                 impl: str = "auto", algorithm: str = "optimized_vfpc",
                 policy_kwargs: dict | None = None, max_fuse: int = 16,
                 exclude_contained: bool = True,
                 dedup_consequents: bool = True, overfetch: int = 8,
                 autotune: bool = True, latency_budget_ms: float | None = None,
                 controller=None):
        if impl not in RULE_IMPLS:
            raise ValueError(f"unknown impl {impl!r}; options: {RULE_IMPLS}")
        if algorithm not in ALGORITHMS:
            raise ValueError(
                f"unknown algorithm {algorithm!r}; options: {sorted(ALGORITHMS)}")
        backend = jax.default_backend()
        self._backend = backend
        # "auto" stays unresolved here: _fn resolves it per dispatch shape
        # from the cross-family plan (DESIGN.md §10)
        self.impl = impl
        self.top_k = top_k
        self.max_fuse = max_fuse
        self.exclude_contained = exclude_contained
        self.dedup_consequents = dedup_consequents
        self.overfetch = max(int(overfetch), 1)
        self.autotune = autotune
        self.algorithm = algorithm
        self.latency_budget_s = (None if latency_budget_ms is None
                                 else float(latency_budget_ms) / 1e3)
        if algorithm == "measured":
            # cost-model fusion: no Policy object — choose_fusion is the
            # serving primitive (DESIGN.md §9)
            if controller is None:
                from repro.costmodel import CostController
                controller = CostController()
            self.policy = None
        else:
            policy_cls, _ = ALGORITHMS[algorithm]
            self.policy = policy_cls(**(policy_kwargs or {}))
        # a controller passed alongside a paper policy still observes every
        # dispatch, so baseline runs calibrate the model the measured mode uses
        self.controller = controller

        self.store = rules if isinstance(rules, RuleStore) else RuleStore(rules)
        self.records: list[RuleServeRecord] = []

    @property
    def rules(self) -> RuleSet:
        return self.store.state.rules          # sole tenant (raises if many)

    @property
    def n_rules(self) -> int:
        return len(self.store.state)

    @property
    def tenants(self) -> tuple:
        return self.store.tenants

    @property
    def dispatches(self) -> int:
        return len(self.records)

    # -- live refresh ----------------------------------------------------------

    def swap_rules(self, rules: RuleSet, warm_to: int | None = None,
                   tenant: str | None = None) -> None:
        """Atomically replace one tenant's served RuleSet (DESIGN.md §8/§12).

        The complete successor arena (device arrays, metric columns, empty
        jit cache) is built first — optionally pre-compiled up to ``warm_to``
        queries so the first post-swap dispatch pays no compile cost — and
        then published with one reference assignment.  Serve calls capture
        the state once, so a query stream never sees a torn table: each
        dispatch is answered entirely by the old arena or entirely by the
        new one.  ``tenant`` defaults to the sole registered tenant.
        """
        if tenant is None:
            names = self.store.tenants
            tenant = names[0] if len(names) == 1 else DEFAULT_TENANT
        warm = ((lambda state: self._warm(state, warm_to, self.top_k))
                if warm_to else None)
        self.store.swap_rules(tenant, rules, warm=warm)

    # -- jitted dispatch -------------------------------------------------------

    def _blocks(self, state: ArenaState, impl_key: str, Qp: int) -> dict:
        if not self.autotune:
            return dict(DEFAULTS[impl_key])
        return tuned_blocks(impl_key, C=max(len(state), 1), T=Qp, W=state.W)

    def _resolve_impl(self, state: ArenaState, Qp: int) -> str:
        impl = self.impl
        if impl != "auto":
            return impl
        plan = (tuned_plan("rules", C=max(len(state), 1), T=Qp, W=state.W)
                if self.autotune else None)
        if plan is not None and plan["impl"] in RULE_IMPLS:
            return plan["impl"]
        return {"tpu": "pallas", "gpu": "matmul"}.get(self._backend, "jnp")

    def _fn(self, state: ArenaState, Qp: int, k: int):
        key = (Qp, k)
        if key in state.jitted:
            return state.jitted[key]
        ante, cons, scores = state.d_ante, state.d_cons, state.d_scores
        excl = self.exclude_contained
        impl = self._resolve_impl(state, Qp)
        if impl in ("jnp", "matmul"):
            blocks = self._blocks(state, f"rules_{impl}", Qp)
            qb = min(blocks["q_block"], Qp)
            score_fn = rule_scores_matmul if impl == "matmul" else rule_scores_jnp

            def fn(baskets):
                s = score_fn(ante, cons, scores, baskets,
                             q_block=qb, exclude_contained=excl)
                return jax.lax.top_k(s, k)
        else:
            interpret = (impl.endswith("_interpret")
                         or self._backend != "tpu")
            base = ("rules_matmul_pallas" if impl.startswith("matmul")
                    else "rules_pallas")
            impl_key = f"{base}_interpret" if interpret else base
            blocks = self._blocks(state, impl_key, Qp)
            score_fn = (rule_scores_matmul_pallas if impl.startswith("matmul")
                        else rule_scores_pallas)

            def fn(baskets):
                s = score_fn(ante, cons, scores, baskets,
                             bq=blocks["bq"], br=blocks["br"],
                             exclude_contained=excl,
                             interpret=interpret)
                return jax.lax.top_k(s, k)
        state.jitted[key] = jax.jit(fn)
        return state.jitted[key]

    def _dispatch(self, state: ArenaState, packed: np.ndarray, k: int):
        """(Q, W) packed baskets → host (Q, k) score values + rule indices."""
        Q = packed.shape[0]
        Qp = bucket_rows(Q)
        if Qp != Q:
            packed = np.concatenate(
                [packed, np.zeros((Qp - Q, state.W), np.uint32)], axis=0)
        vals, idx = self._fn(state, Qp, k)(jnp.asarray(packed))
        return np.asarray(vals)[:Q], np.asarray(idx)[:Q]

    def _warm(self, state: ArenaState, max_queries: int,
              top_k: int | None = None):
        k = max(min(self.top_k if top_k is None else top_k, len(state)), 0)
        if k == 0:
            return
        kf = min(k * self.overfetch, len(state)) if self.dedup_consequents else k
        b = MIN_QUERY_BUCKET
        while True:
            self._dispatch(state, np.zeros((b, state.W), np.uint32), kf)
            if b >= max_queries:
                break
            b *= 2

    def warmup(self, max_queries: int, top_k: int | None = None):
        """Pre-compile every pow2 query bucket up to ``max_queries`` (and run
        the autotuner) so no dispatch in the serving loop pays compile cost."""
        self._warm(self.store.state, max_queries, top_k)

    # -- host driver -----------------------------------------------------------

    def _decode(self, state: ArenaState, vals: np.ndarray, idx: np.ndarray,
                k: int):
        dedup = self.dedup_consequents
        out = []
        for q in range(vals.shape[0]):
            recs = []
            seen: set = set()
            for j in range(vals.shape[1]):
                # -inf is the kernel's no-match sentinel; +inf is a legal score
                # (legacy missing-consequent lift) and must decode normally
                if np.isneginf(vals[q, j]) or len(recs) >= k:
                    break
                r = int(idx[q, j])
                cons = state.cons_tuple(r)
                if dedup:
                    if cons in seen:
                        continue    # a lower-scored rule for the same consequent
                    seen.add(cons)
                recs.append(Recommendation(
                    cons, float(state.conf64[r]), float(state.lift64[r]),
                    float(vals[q, j])))
            out.append(recs)
        return out

    def serve(self, batches, top_k: int | None = None,
              tenant: str | None = None):
        """Answer a queue of basket batches with policy-fused dispatches.

        Args:
          batches: sequence of batches; each batch is a list of queries — a
            query is a basket (iterable of item ids, served under the
            default tenant) or a ``(tenant, basket)`` pair; mixed-tenant
            batches share one fused arena dispatch (DESIGN.md §12).
          top_k: recommendations per query (default: engine top_k).
          tenant: serve every query under this tenant (overrides pairs).

        Returns ``(results, records)`` — ``results[b][q]`` is the list of
        :class:`Recommendation` for basket ``q`` of batch ``b``, and
        ``records`` the per-dispatch :class:`RuleServeRecord` trace (also kept
        on ``self.records``).
        """
        state = self.store.state     # snapshot: one consistent table per call
        n_rules = len(state)
        k = max(min(self.top_k if top_k is None else top_k, n_rules), 0)
        batches = [as_tenant_pairs(b, tenant) for b in batches]
        results: list = []
        records: list[RuleServeRecord] = []
        history: list[PhaseStats] = []
        if n_rules == 0 or k == 0:            # no rules: everything is empty
            results = [[[] for _ in b] for b in batches]
            self.records = records
            return results, records

        i, phase_idx = 0, 0
        while i < len(batches):
            if self.policy is None:   # measured: predicted latency vs budget
                # per-query work: rule·word containment tests plus the top-k
                # result transfer (8 B per fetched rule slot) in the shared
                # ops basis (roofline.XFER_OPS_PER_BYTE, DESIGN.md §10)
                kf_est = (min(k * self.overfetch, n_rules)
                          if self.dedup_consequents else k)
                per_query = (float(n_rules) * state.W
                             + 8.0 * kf_est * XFER_OPS_PER_BYTE)
                work = per_query * max(len(batches[i]), 1)
                nfuse = self.controller.choose_fusion(
                    work_per_unit=work, queued=len(batches) - i,
                    max_fuse=self.max_fuse,
                    latency_budget_s=self.latency_budget_s)
                # uncalibrated: dispatch one batch — it is the calibration
                nfuse = 1 if nfuse is None else int(nfuse)
            else:
                prev = history[-1] if history else None
                prev2 = history[-2] if len(history) > 1 else None
                mode, val = self.policy.decide(prev, prev2)
                if mode == "width":
                    nfuse = int(val)
                else:  # budget_alpha: fuse ⌊α⌋ queued batches (α=1 ⇒
                       # per-batch, the drivers' "no widening" semantics)
                    nfuse = int(np.floor(val))
            nfuse = max(1, min(nfuse, self.max_fuse, len(batches) - i))
            group = batches[i:i + nfuse]
            sizes = [len(b) for b in group]
            flat = [pair for batch in group for pair in batch]

            t0 = time.perf_counter()
            with current_tracer().span(
                    "serve.engine_dispatch", n_batches=nfuse,
                    n_queries=len(flat), n_rules=n_rules,
                    impl=self.impl) as dspan:
                if flat:
                    kf = (min(k * self.overfetch, n_rules)
                          if self.dedup_consequents else k)
                    vals, idx = self._dispatch(state, state.pack(flat), kf)
                    decoded = self._decode(state, vals, idx, k)
                else:
                    decoded = []
            elapsed = time.perf_counter() - t0
            dspan.set(elapsed_seconds=elapsed)

            off = 0
            for sz in sizes:
                results.append(decoded[off:off + sz])
                off += sz
            n_q = len(flat)
            if self.controller is not None and n_q:
                self.controller.observe_serve(
                    float(n_rules) * state.W + 8.0 * kf * XFER_OPS_PER_BYTE,
                    n_q, elapsed)
            history.append(PhaseStats(n_rules * max(n_q, 1),
                                      max(n_q, 1), elapsed))
            records.append(RuleServeRecord(phase_idx, nfuse, n_q, elapsed))
            i += nfuse
            phase_idx += 1
        self.records = records
        return results, records

    def query(self, baskets, top_k: int | None = None,
              tenant: str | None = None):
        """Single-batch convenience: recommendations for one list of baskets
        (bare baskets or ``(tenant, basket)`` pairs)."""
        results, _ = self.serve([list(baskets)], top_k=top_k, tenant=tenant)
        return results[0]
