from .adamw import AdamWConfig, apply_updates, init_state, schedule, state_axes

__all__ = ["AdamWConfig", "apply_updates", "init_state", "schedule", "state_axes"]
