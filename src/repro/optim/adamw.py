"""Sharded AdamW with global-norm clipping, cosine schedule, and an optional
int8 gradient-compression (error-feedback) stage.

Optimizer state lives in f32 and inherits the parameter sharding (params are
already fully sharded across both mesh axes under the default rules — the
ZeRO-3 regime — so m/v are sharded identically at 2× param bytes).

Gradient compression: ``compress_grads`` quantizes gradients to int8 with a
per-tensor scale and keeps the quantization residual in an error-feedback
buffer (added back next step).  On a real multi-pod deployment the quantized
tensor is what crosses the pod-interconnect all-reduce; in this single-
controller formulation it documents/measures the numerics (tests show
convergence is preserved) while the collective itself stays XLA-managed.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    compress: bool = False       # int8 gradient compression w/ error feedback


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_state(params, cfg: AdamWConfig):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compress:
        state["err"] = jax.tree.map(f32, params)
    return state


def _quantize_int8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads, err):
    """int8 quantize with error feedback. Returns (dequantized grads, new err)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize_int8(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq
    flat = jax.tree.map(one, grads, err)
    deq = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    if cfg.compress:
        grads, new_err = compress_grads(grads, state["err"])
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.compress:
        new_state["err"] = new_err
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def state_axes(param_axes, cfg: AdamWConfig):
    """Optimizer-state logical axes (mirror params; step is replicated)."""
    out = {"m": param_axes, "v": param_axes, "step": ()}
    if cfg.compress:
        out["err"] = param_axes
    return out
