"""repro: MapReduce-based Apriori pass-fusion (Singh, Garg & Mishra 2018) as a
production JAX framework — mining engine, LM model zoo, multi-pod launch,
roofline tooling.  See DESIGN.md."""

__version__ = "1.0.0"
