"""Pass-combining width policies — shared by the mining drivers, the serving
engine's multi-step decode fusion, and the training loop's microbatch fusion.

Each policy decides, from the statistics of the two preceding phases, either a
fixed number of passes for the next phase (``width``) or a candidate budget
(``budget``).  These are line-by-line transcriptions of the paper's drivers:

  SPC    — width 1 always.
  FPC    — fixed width (default 3).                        [Lin et al., baseline]
  DPC    — budget ct = α·|L|, α from the previous phase's absolute elapsed
           time vs threshold β.                            [Lin et al., baseline]
  VFPC   — width 2 while per-phase candidate counts are non-decreasing, then
           width += 3 per phase (reset to 2 on an increase).   [paper Alg. 3]
  ETDPC  — budget ct = α·|L|, α from the *relative* elapsed times of the two
           preceding phases (β₁, β₂ scaled thresholds).        [paper Alg. 4]

Elapsed-time thresholds are the paper's 40 s / 60 s / 60 s multiplied by
``time_scale`` (default 1e-3): XLA dispatch overhead is ~1000× smaller than
Hadoop job scheduling, and the paper's own point is that only *relative* times
are trustworthy — which is exactly what survives the rescaling.

Beyond the paper, ``measured`` (MeasuredPolicy) replaces the transcribed
β-threshold tables with predictions from the calibrated cost model
(``repro/costmodel/``, DESIGN.md §9); the five paper policies stay bit-exact
as baselines.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class PhaseStats:
    """What a policy is allowed to observe about a completed phase."""
    n_candidates: int          # total candidates generated in the phase
    n_frequent_last: int       # |L| of the phase's last level (paper's |L_{k-1}|)
    elapsed: float             # wall-clock seconds of the phase


class Policy:
    """Base: subclasses implement ``decide`` → ("width", n) or ("budget", ct)."""

    def decide(self, prev: PhaseStats | None, prev2: PhaseStats | None):
        raise NotImplementedError

    @property
    def name(self) -> str:
        return type(self).__name__


class SPCPolicy(Policy):
    def decide(self, prev, prev2):
        return ("width", 1)


class FPCPolicy(Policy):
    def __init__(self, npass: int = 3):
        self.npass = npass

    def decide(self, prev, prev2):
        return ("width", self.npass)


class DPCPolicy(Policy):
    """Lin et al.'s DPC: α > 1 iff previous phase was 'fast' vs absolute β."""

    def __init__(self, alpha_fast: float = 2.0, beta: float = 60.0,
                 time_scale: float = 1e-3):
        self.alpha_fast = alpha_fast
        self.beta = beta * time_scale

    def decide(self, prev, prev2):
        if prev is None:
            return ("budget_alpha", 1.0)
        alpha = self.alpha_fast if prev.elapsed < self.beta else 1.0
        return ("budget_alpha", alpha)


class VFPCPolicy(Policy):
    """Paper Algorithm 3 driver lines 10–16."""

    def __init__(self):
        self._npass = 2

    def decide(self, prev, prev2):
        if prev is None or prev2 is None:
            self._npass = 2
        elif prev.n_candidates < prev2.n_candidates:
            self._npass += 3
        else:
            self._npass = 2
        return ("width", self._npass)


class ETDPCPolicy(Policy):
    """Paper Algorithm 4 driver lines 13–22."""

    def __init__(self, beta1: float = 40.0, beta2: float = 60.0,
                 time_scale: float = 1e-3):
        self.beta1 = beta1 * time_scale
        self.beta2 = beta2 * time_scale

    def decide(self, prev, prev2):
        if prev is None:
            return ("budget_alpha", 1.0)
        et = prev.elapsed
        etprev = prev2.elapsed if prev2 is not None else et
        if etprev < et:
            if et <= self.beta1:
                alpha = 3.0
            elif et < self.beta2:
                alpha = 2.0
            else:
                alpha = 1.0
        else:
            alpha = 3.0 if etprev >= 1.5 * et else 2.0
        return ("budget_alpha", alpha)


class MeasuredPolicy(Policy):
    """Beyond-paper ``measured`` variant: width from the calibrated cost
    model (DESIGN.md §9) instead of transcribed β thresholds.

    Delegates to :meth:`repro.costmodel.CostController.choose_width`, which
    minimizes predicted cost per Apriori level — one fitted job overhead
    amortized over ``w`` fused passes vs the un-pruned counting work they
    add.  Until the model has observed at least one counting job the paper's
    ETDPC table decides (the thresholds are a sane uncalibrated prior and the
    first phase needs *some* answer); every later decision is prediction-
    driven and recorded in the controller's telemetry.

    The paper-faithful policies above are deliberately untouched: they remain
    bit-identical baselines (``tests/test_policies.py`` pins their decision
    tables line-by-line against the pseudo-code).
    """

    def __init__(self, controller=None, max_width: int = 3,
                 time_scale: float = 1e-3):
        from repro.costmodel import CostController
        self.controller = (controller if controller is not None
                           else CostController(max_width=max_width))
        self._fallback = ETDPCPolicy(time_scale=time_scale)

    def decide(self, prev, prev2):
        width = self.controller.choose_width(prev, prev2)
        if width is None:
            return self._fallback.decide(prev, prev2)
        # budget semantics, not a raw width: generation stops once α·|L|
        # candidates are spent, so a mispredicted lattice explosion costs at
        # most the work the model already priced in
        return ("budget_alpha", width)


ALGORITHMS = {
    "spc": (SPCPolicy, False),
    "fpc": (FPCPolicy, False),
    "dpc": (DPCPolicy, False),
    "vfpc": (VFPCPolicy, False),
    "etdpc": (ETDPCPolicy, False),
    "optimized_vfpc": (VFPCPolicy, True),
    "optimized_etdpc": (ETDPCPolicy, True),
    # beyond-paper: calibrated cost-model widths (skipped pruning, like the
    # paper's best optimized_* drivers it competes with in bench_costmodel)
    "measured": (MeasuredPolicy, True),
}
