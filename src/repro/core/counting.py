"""Device-side support counting used inside the MapReduce runtime.

These functions are traced (called inside ``jax.jit`` / ``shard_map``), so they
take pre-padded static shapes and never touch the host.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.support_count import support_count_pallas
from repro.kernels.ops import _empty_cand_correction, _support_count_jnp


def local_counts(db_local: jax.Array, cands: jax.Array, impl: str,
                 txn_block: int = 4096) -> jax.Array:
    """Per-device support counts (the Mapper + Combiner of one split).

    Args:
      db_local: (Nd, W) uint32 — this device's transaction shard (zero-padded).
      cands:    (C, W) uint32 — candidate bitmasks (replicated, zero-padded,
                C a multiple of the kernel block).
      impl:     "pallas" | "pallas_interpret" | "jnp".

    Returns: (C,) int32 local counts.
    """
    if impl == "jnp":
        block = min(txn_block, max(db_local.shape[0], 1))
        return _support_count_jnp(cands, db_local, block=block)
    if impl in ("pallas", "pallas_interpret"):
        bc = min(256, cands.shape[0])
        bt = 512
        nd = db_local.shape[0]
        pad = (-nd) % bt
        if pad:
            db_local = jnp.concatenate(
                [db_local, jnp.zeros((pad, db_local.shape[1]), db_local.dtype)], axis=0)
        out = support_count_pallas(cands, db_local, bc=bc, bt=bt,
                                   interpret=(impl == "pallas_interpret"))
        return out - _empty_cand_correction(cands, pad)
    raise ValueError(f"unknown impl {impl!r}")


def local_counts_vertical(vdb_local: jax.Array, cand_idx: jax.Array,
                          block: int = 2048) -> jax.Array:
    """Vertical-layout support counting (§Perf iteration M-D).

    vdb_local: (I+1, Tw) uint32 — item-major transaction bitmaps for this
      shard; row I is the valid-transaction mask (AND identity for padding).
    cand_idx: (C, kmax) int32 — item ids per candidate, padded with I.

    count = popcount(AND of the candidate's item rows).  Work per candidate is
    O(k · N/32) words instead of the horizontal O(N · W) — the vertical data
    layout of Jen et al. ([15] in the paper), adopted as a beyond-paper
    optimization of the counting phase.
    """
    C, kmax = cand_idx.shape
    pad = (-C) % block
    if pad:
        cand_idx = jnp.concatenate(
            [cand_idx, jnp.full((pad, kmax), vdb_local.shape[0] - 1,
                                cand_idx.dtype)], axis=0)
    blocks = cand_idx.reshape(-1, block, kmax)

    def body(_, idx_blk):
        rows = vdb_local[idx_blk]                    # (block, kmax, Tw)
        acc = rows[:, 0]
        for j in range(1, kmax):                     # kmax tiny: unrolled ANDs
            acc = acc & rows[:, j]
        cnt = jax.lax.population_count(acc).astype(jnp.int32).sum(-1)
        return None, cnt

    _, counts = jax.lax.scan(body, None, blocks)
    return counts.reshape(-1)[:C]
