"""Device-side support counting used inside the MapReduce runtime.

These functions are traced (called inside ``jax.jit`` / ``shard_map``), so they
take pre-padded static shapes and never touch the host.  Block sizes are
decided *before* tracing by the autotuner (:mod:`repro.kernels.autotune`) and
passed in as static keywords.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.support_count import (support_count_matmul,
                                         support_count_matmul_pallas,
                                         support_count_pallas)
from repro.kernels.ops import _empty_cand_correction, _support_count_jnp
from repro.kernels.vertical_count import (DEFAULT_BLOCK, DEFAULT_BT,
                                          vertical_count_jnp,
                                          vertical_count_matmul,
                                          vertical_count_matmul_pallas,
                                          vertical_count_pallas)


def local_counts(db_local: jax.Array, cands: jax.Array, impl: str,
                 txn_block: int = 4096, bc: int | None = None,
                 bt: int = 512) -> jax.Array:
    """Per-device support counts (the Mapper + Combiner of one split).

    Args:
      db_local: (Nd, W) uint32 — this device's transaction shard (zero-padded).
      cands:    (C, W) uint32 — candidate bitmasks (replicated, zero-padded,
                C a multiple of the kernel block).
      impl:     "pallas" | "pallas_interpret" | "jnp" | "matmul" |
                "matmul_pallas" | "matmul_pallas_interpret" (DESIGN.md §10).
      txn_block / bc / bt: block sizes (autotuned by the runtime).

    Returns: (C,) int32 local counts.
    """
    if impl == "jnp":
        block = min(txn_block, max(db_local.shape[0], 1))
        return _support_count_jnp(cands, db_local, block=block)
    if impl == "matmul":
        block = min(txn_block, max(db_local.shape[0], 1))
        return support_count_matmul(cands, db_local, block=block)
    if impl in ("pallas", "pallas_interpret", "matmul_pallas",
                "matmul_pallas_interpret"):
        bc = min(bc or 256, cands.shape[0])
        nd = db_local.shape[0]
        pad = (-nd) % bt
        if pad:
            db_local = jnp.concatenate(
                [db_local, jnp.zeros((pad, db_local.shape[1]), db_local.dtype)], axis=0)
        fn = (support_count_matmul_pallas if impl.startswith("matmul")
              else support_count_pallas)
        out = fn(cands, db_local, bc=bc, bt=bt,
                 interpret=impl.endswith("_interpret"))
        return out - _empty_cand_correction(cands, pad)
    raise ValueError(f"unknown impl {impl!r}")


def local_counts_vertical(vdb_local: jax.Array, cand_idx: jax.Array,
                          impl: str = "jnp", block: int = DEFAULT_BLOCK,
                          bc: int = 256, bt: int = DEFAULT_BT) -> jax.Array:
    """Vertical-layout support counting (§Perf iteration M-D).

    vdb_local: (I+1, Tw) uint32 — item-major transaction bitmaps for this
      shard; row I is the valid-transaction mask (AND identity for padding).
    cand_idx: (C, kmax) int32 — item ids per candidate, padded with I.
    impl: "jnp" (blocked gather-scan) | "pallas" | "pallas_interpret"
      (tiled popcount-AND kernel, kernels/vertical_count.py) | "matmul" |
      "matmul_pallas" | "matmul_pallas_interpret" (bit-plane membership
      matmul, DESIGN.md §10).

    count = popcount(AND of the candidate's item rows).  Work per candidate is
    O(k · N/32) words instead of the horizontal O(N · W) — the vertical data
    layout of Jen et al. ([15] in the paper), adopted as a beyond-paper
    optimization of the counting phase.
    """
    if impl in ("pallas", "pallas_interpret"):
        return vertical_count_pallas(vdb_local, cand_idx, bt=bt,
                                     interpret=(impl == "pallas_interpret"))
    if impl in ("matmul_pallas", "matmul_pallas_interpret"):
        return vertical_count_matmul_pallas(
            vdb_local, cand_idx, bc=bc, bt=bt,
            interpret=impl.endswith("_interpret"))
    if impl == "matmul":
        return vertical_count_matmul(vdb_local, cand_idx, block=block)
    if impl == "jnp":
        return vertical_count_jnp(vdb_local, cand_idx, block=block)
    raise ValueError(f"unknown vertical impl {impl!r}")
