"""Candidate generation: ``apriori_gen`` (join + prune) and ``non_apriori_gen`` (join only).

Semantics match the classic Agrawal–Srikant generation exactly:

* **join** — two size-``k`` itemsets join iff they share their ``k-1`` *lowest*
  items (the sorted-order prefix) and differ in the highest one.  With bitmasks
  that is: ``popcount(a | b) == k + 1`` and ``highest_bit(a & b) < lowest_bit(a ^ b)``.
  Each ``(k+1)``-candidate is produced by exactly one unordered pair, so no
  dedup pass is needed and candidate counts are comparable to the paper's.
* **prune** — drop a candidate if any of its ``k``-subsets is absent from the
  previous level (the Apriori property).  ``non_apriori_gen`` skips this — the
  paper's §4.2 optimization — producing a superset of un-pruned candidates whose
  false positives are eliminated by support counting (integrity preserved).

Generation is host-side vectorized numpy (the Hadoop analogue is the in-mapper
trie construction; see DESIGN.md §2 for why this lives on the host in the TPU
adaptation).  The heavy phase — support counting over the transaction shards —
is the device/`shard_map` path in :mod:`repro.core.counting`.
"""

from __future__ import annotations

import numpy as np

from .bitset import WORD_BITS, MaskIndex

_DEF_BLOCK = 1024


def _bit_matrix(masks: np.ndarray) -> np.ndarray:
    """(N, W) uint32 → (N, W*32) uint8 bit expansion (bit b of word w at w*32+b)."""
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (masks[:, :, None] >> shifts[None, None, :]) & np.uint32(1)
    return bits.reshape(masks.shape[0], -1).astype(np.uint8)


def _floor_log2(x: np.ndarray) -> np.ndarray:
    """floor(log2(x)) for positive ints via the float64 exponent field.

    Exact for x < 2^53 (uint32 qualifies); ~3× faster than np.log2 because it
    is a cast + shift + mask instead of a transcendental (§Perf iteration M-A).
    Zeros map to -1023-ish garbage — callers must mask.
    """
    f = x.astype(np.float64)
    return ((f.view(np.uint64) >> np.uint64(52)).astype(np.int64) & 0x7FF) - 1023


def _hi_lo_3d(masks: np.ndarray):
    """Highest and lowest set-bit indices for (..., W) uint32 arrays."""
    *lead, W = masks.shape
    hi = np.full(lead, -1, dtype=np.int64)
    lo = np.full(lead, W * WORD_BITS + 1, dtype=np.int64)
    for wi in range(W):
        word = masks[..., wi].astype(np.int64)
        nz = word != 0
        if not nz.any():
            continue
        bl = _floor_log2(np.where(nz, word, 1))
        hi = np.where(nz, wi * WORD_BITS + bl, hi)
        bl_lo = _floor_log2(np.where(nz, word & -word, 1))
        lo = np.where(nz & (lo == W * WORD_BITS + 1), wi * WORD_BITS + bl_lo, lo)
    return hi, lo


def join(prev: np.ndarray, k_prev: int, block: int = _DEF_BLOCK) -> np.ndarray:
    """Classic Apriori join of size-``k_prev`` itemsets → size-``k_prev+1`` candidates.

    Blocked pairwise evaluation keeps peak memory at ``O(block² · W)``.
    Output is canonically ordered (lexicographic by words, high word first).
    """
    prev = np.asarray(prev, dtype=np.uint32)
    n, W = prev.shape
    if n < 2:
        return np.zeros((0, W), dtype=np.uint32)
    out_blocks = []
    for bi in range(0, n, block):
        a = prev[bi:bi + block]
        for bj in range(bi, n, block):
            b = prev[bj:bj + block]
            diff = a[:, None, :] ^ b[None, :, :]
            pc_diff = np.bitwise_count(diff).sum(-1)
            cand_pair = pc_diff == 2  # share exactly k_prev-1 items
            if bi == bj:  # only strict upper triangle on the diagonal block
                cand_pair &= np.triu(np.ones(cand_pair.shape, dtype=bool), k=1)
            ii, jj = np.nonzero(cand_pair)
            if ii.size == 0:
                continue
            # §Perf iteration M-B: evaluate the prefix condition only on the
            # ~O(n·deg) surviving pairs instead of the full O(block²) tile.
            ai, bj_rows = a[ii], b[jj]
            hi, _ = _hi_lo_3d(ai & bj_rows)
            _, lo_d = _hi_lo_3d(ai ^ bj_rows)
            keep = hi < lo_d
            if keep.any():
                out_blocks.append(ai[keep] | bj_rows[keep])
    if not out_blocks:
        return np.zeros((0, W), dtype=np.uint32)
    cands = np.concatenate(out_blocks, axis=0)
    order = np.lexsort(tuple(cands[:, wi] for wi in range(W)))
    return cands[order]


def prune(cands: np.ndarray, prev: np.ndarray, k_prev: int) -> np.ndarray:
    """Apriori-property prune: keep candidates all of whose ``k_prev``-subsets ∈ prev."""
    cands = np.asarray(cands, dtype=np.uint32)
    if cands.shape[0] == 0:
        return cands
    index = MaskIndex(prev)
    bitmat = _bit_matrix(cands)
    rows, cols = np.nonzero(bitmat)
    subsets = cands[rows].copy()
    subsets[np.arange(rows.size), cols // WORD_BITS] ^= (
        np.uint32(1) << (cols % WORD_BITS).astype(np.uint32))
    present = index.contains(subsets)
    missing_per_row = np.bincount(rows, weights=(~present).astype(np.int64),
                                  minlength=cands.shape[0])
    return cands[missing_per_row == 0]


def apriori_gen(prev: np.ndarray, k_prev: int, block: int = _DEF_BLOCK) -> np.ndarray:
    """join + prune (the paper's ``apriori-gen()``)."""
    return prune(join(prev, k_prev, block=block), prev, k_prev)


def non_apriori_gen(prev: np.ndarray, k_prev: int, block: int = _DEF_BLOCK) -> np.ndarray:
    """join only — skipped-pruning (the paper's ``non-apriori-gen()``, §4.2)."""
    return join(prev, k_prev, block=block)
