"""Candidate generation: ``apriori_gen`` (join + prune) and ``non_apriori_gen`` (join only).

Semantics match the classic Agrawal–Srikant generation exactly:

* **join** — two size-``k`` itemsets join iff they share their ``k-1`` *lowest*
  items (the sorted-order prefix) and differ in the highest one.  With bitmasks
  that is: ``popcount(a | b) == k + 1`` and ``highest_bit(a & b) < lowest_bit(a ^ b)``.
  Each ``(k+1)``-candidate is produced by exactly one unordered pair, so no
  dedup pass is needed and candidate counts are comparable to the paper's.
* **prune** — drop a candidate if any of its ``k``-subsets is absent from the
  previous level (the Apriori property).  ``non_apriori_gen`` skips this — the
  paper's §4.2 optimization — producing a superset of un-pruned candidates whose
  false positives are eliminated by support counting (integrity preserved).

Generation is host-side vectorized numpy (the Hadoop analogue is the in-mapper
trie construction; see DESIGN.md §2 for why this lives on the host in the TPU
adaptation).  The heavy phase — support counting over the transaction shards —
is the device/`shard_map` path in :mod:`repro.core.counting`.

``speculative_join`` supports the async phase pipeline (DESIGN.md §4): while a
counting job is in flight, the *next* phase's join is computed over the current
level's un-filtered candidates with parent bookkeeping, so that once the keep
mask arrives the exact ``join(L)`` is recovered by pair filtering instead of a
fresh O(|L|²) pass.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .bitset import WORD_BITS, MaskIndex, highest_bit_index, lowest_bit_index

_DEF_BLOCK = 1024


def _bit_matrix(masks: np.ndarray) -> np.ndarray:
    """(N, W) uint32 → (N, W*32) uint8 bit expansion (bit b of word w at w*32+b)."""
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (masks[:, :, None] >> shifts[None, None, :]) & np.uint32(1)
    return bits.reshape(masks.shape[0], -1).astype(np.uint8)


def _join_pairs_prefix(prev: np.ndarray):
    """Prefix-grouped join: O(output) instead of O(n²) pair tests.

    Two size-``k`` itemsets join iff they share their ``k-1`` lowest items —
    i.e. iff they are identical after clearing the highest bit.  Grouping rows
    by that prefix (the flat-array analogue of walking the paper's trie level)
    means *every* in-group pair joins and no cross-group pair does, so the
    join is exact pair enumeration over the groups (§Perf iteration M-E).
    """
    prev = np.asarray(prev, dtype=np.uint32)
    n, W = prev.shape
    hi = highest_bit_index(prev)                   # (n,) ; -1 for empty rows
    prefix = prev.copy()
    valid = hi >= 0
    rows = np.nonzero(valid)[0]
    prefix[rows, hi[valid] // WORD_BITS] ^= (
        np.uint32(1) << (hi[valid] % WORD_BITS).astype(np.uint32))
    _, group_ids = np.unique(prefix, axis=0, return_inverse=True)
    order = np.argsort(group_ids, kind="stable")   # rows grouped, stable
    sizes = np.bincount(group_ids)
    starts = np.zeros(sizes.size + 1, np.int64)
    np.cumsum(sizes, out=starts[1:])
    left_parts, right_parts = [], []
    for s in np.unique(sizes):
        if s < 2:
            continue
        g_starts = starts[:-1][sizes == s]         # (G,) groups of this size
        p, q = np.triu_indices(int(s), k=1)        # local pair indices
        left_parts.append((g_starts[:, None] + p[None, :]).ravel())
        right_parts.append((g_starts[:, None] + q[None, :]).ravel())
    if not left_parts:
        return (np.zeros((0, W), dtype=np.uint32),
                np.zeros(0, np.int64), np.zeros(0, np.int64))
    left = order[np.concatenate(left_parts)]       # back to original row ids
    right = order[np.concatenate(right_parts)]
    cands = prev[left] | prev[right]
    order_out = np.lexsort(tuple(cands[:, wi] for wi in range(W)))
    return cands[order_out], left[order_out], right[order_out]


def join_pairs(prev: np.ndarray, k_prev: int, block: int = _DEF_BLOCK,
               method: str = "prefix"):
    """Classic Apriori join with parent bookkeeping.

    Returns ``(cands, left, right)`` where ``cands[i] = prev[left[i]] |
    prev[right[i]]``.  ``cands`` is canonically ordered (lexicographic by
    words, high word first).  ``method="prefix"`` (default) enumerates pairs
    within shared-(k-1)-prefix groups — O(output) work; ``method="pairwise"``
    is the legacy blocked all-pairs evaluation (peak memory ``O(block² · W)``),
    kept as the pre-pipeline baseline for A/B benchmarks.  Both produce
    byte-identical results.
    """
    prev = np.asarray(prev, dtype=np.uint32)
    n, W = prev.shape
    empty = (np.zeros((0, W), dtype=np.uint32),
             np.zeros(0, np.int64), np.zeros(0, np.int64))
    if n < 2:
        return empty
    if method == "prefix":
        return _join_pairs_prefix(prev)
    out_blocks, left_blocks, right_blocks = [], [], []
    for bi in range(0, n, block):
        a = prev[bi:bi + block]
        for bj in range(bi, n, block):
            b = prev[bj:bj + block]
            diff = a[:, None, :] ^ b[None, :, :]
            pc_diff = np.bitwise_count(diff).sum(-1)
            cand_pair = pc_diff == 2  # share exactly k_prev-1 items
            if bi == bj:  # only strict upper triangle on the diagonal block
                cand_pair &= np.triu(np.ones(cand_pair.shape, dtype=bool), k=1)
            ii, jj = np.nonzero(cand_pair)
            if ii.size == 0:
                continue
            # §Perf iteration M-B: evaluate the prefix condition only on the
            # ~O(n·deg) surviving pairs instead of the full O(block²) tile.
            ai, bj_rows = a[ii], b[jj]
            hi = highest_bit_index(ai & bj_rows)
            lo_d = lowest_bit_index(ai ^ bj_rows)
            keep = hi < lo_d
            if keep.any():
                out_blocks.append(ai[keep] | bj_rows[keep])
                left_blocks.append(bi + ii[keep])
                right_blocks.append(bj + jj[keep])
    if not out_blocks:
        return empty
    cands = np.concatenate(out_blocks, axis=0)
    left = np.concatenate(left_blocks).astype(np.int64)
    right = np.concatenate(right_blocks).astype(np.int64)
    order = np.lexsort(tuple(cands[:, wi] for wi in range(W)))
    return cands[order], left[order], right[order]


def join(prev: np.ndarray, k_prev: int, block: int = _DEF_BLOCK,
         method: str = "prefix") -> np.ndarray:
    """Classic Apriori join of size-``k_prev`` itemsets → size-``k_prev+1`` candidates."""
    return join_pairs(prev, k_prev, block=block, method=method)[0]


@dataclasses.dataclass
class SpecJoin:
    """A speculative join of a level's *candidates* ``C`` (superset of its
    frequents ``L``), computed while the level's counting job is in flight.

    ``cands[i] = src[left[i]] | src[right[i]]``.  Because every
    ``(k+1)``-itemset arises from exactly one unordered pair and the canonical
    lexsort order is preserved under subsetting, filtering pairs with the keep
    mask over ``C`` reproduces ``join(L)`` exactly — rows, order and all.
    """
    cands: np.ndarray       # (M, W) joined candidates, canonically ordered
    left: np.ndarray        # (M,) parent row index into the source level
    right: np.ndarray       # (M,)
    n_src: int              # number of source-level candidates (len of keep)

    def resolve(self, keep: np.ndarray) -> np.ndarray:
        """Exact ``join(src[keep])`` via pair filtering (no re-join)."""
        assert keep.shape[0] == self.n_src, (keep.shape, self.n_src)
        sel = keep[self.left] & keep[self.right]
        return self.cands[sel]


def speculative_join(cands: np.ndarray, k: int,
                     block: int = _DEF_BLOCK) -> SpecJoin:
    """Join the un-filtered candidates of level ``k`` with parent bookkeeping."""
    out, left, right = join_pairs(cands, k, block=block, method="prefix")
    return SpecJoin(out, left, right, n_src=np.asarray(cands).shape[0])


def prune(cands: np.ndarray, prev: np.ndarray, k_prev: int) -> np.ndarray:
    """Apriori-property prune: keep candidates all of whose ``k_prev``-subsets ∈ prev."""
    cands = np.asarray(cands, dtype=np.uint32)
    if cands.shape[0] == 0:
        return cands
    index = MaskIndex(prev)
    bitmat = _bit_matrix(cands)
    rows, cols = np.nonzero(bitmat)
    subsets = cands[rows].copy()
    subsets[np.arange(rows.size), cols // WORD_BITS] ^= (
        np.uint32(1) << (cols % WORD_BITS).astype(np.uint32))
    present = index.contains(subsets)
    missing_per_row = np.bincount(rows, weights=(~present).astype(np.int64),
                                  minlength=cands.shape[0])
    return cands[missing_per_row == 0]


def apriori_gen(prev: np.ndarray, k_prev: int, block: int = _DEF_BLOCK,
                method: str = "prefix") -> np.ndarray:
    """join + prune (the paper's ``apriori-gen()``)."""
    return prune(join(prev, k_prev, block=block, method=method), prev, k_prev)


def non_apriori_gen(prev: np.ndarray, k_prev: int, block: int = _DEF_BLOCK,
                    method: str = "prefix") -> np.ndarray:
    """join only — skipped-pruning (the paper's ``non-apriori-gen()``, §4.2)."""
    return join(prev, k_prev, block=block, method=method)
