"""MapReduce runtime on a JAX device mesh.

Hadoop concept → this runtime:

* InputSplit            → equal transaction shards along the ``data`` mesh axis
* Mapper + Combiner     → per-device support-count kernel over the local shard
                          (local sums never leave the device uncombined)
* shuffle + Reducer     → one ``jax.lax.psum`` over the ``data`` axis
* one MapReduce *job*   → one jitted ``shard_map`` dispatch (host sync included)

The runtime tracks dispatch and compile counts: the paper's objective —
minimizing the number of scheduled jobs — maps to minimizing dispatches here,
and re-compiles are the analogue of job setup cost.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .counting import local_counts, local_counts_vertical
from .bitset import masks_to_indices, popcount_rows, vertical_pack


@dataclasses.dataclass
class RuntimeStats:
    dispatches: int = 0
    compiles: int = 0
    rows_counted: int = 0  # candidates counted across all dispatches


class MapReduceRuntime:
    """Support-counting runtime over a 1-D (or larger) mesh.

    Args:
      mesh: a Mesh containing a ``data`` axis (other axes are unused here but
        allowed, so the production (data, model) mesh can be passed directly).
        Defaults to a 1-D mesh over all local devices.
      impl: counting implementation — "jnp" (default off-TPU), "pallas",
        "pallas_interpret".
      cand_axis: optional mesh axis name to additionally shard *candidates*
        over (2-D decomposition; beyond-paper, see DESIGN.md). None replicates
        candidates, matching the paper (every mapper holds the full trie).
    """

    def __init__(self, mesh: Mesh | None = None, impl: str | None = None,
                 cand_axis: str | None = None):
        if mesh is None:
            mesh = jax.make_mesh((len(jax.devices()),), ("data",),
                                 axis_types=(jax.sharding.AxisType.Auto,))
        if impl is None:
            # TPU: dense horizontal Pallas kernel; CPU: vertical layout
            # (§Perf iteration M-D — gather-heavy but 10-70× less word work)
            impl = "pallas" if jax.default_backend() == "tpu" else "vertical"
        self.mesh = mesh
        self.impl = impl
        self.cand_axis = cand_axis
        self.stats = RuntimeStats()
        self._shape_cache: set = set()
        self._jitted = {}
        self._n_items: int | None = None

    @property
    def n_data_shards(self) -> int:
        return self.mesh.shape["data"]

    # -- data distribution ---------------------------------------------------

    def scatter_db(self, db_masks: np.ndarray, n_items: int | None = None):
        """Zero-pad rows to the shard multiple and place shards on devices.

        Horizontal impls return the (N, W) row-sharded matrix; the vertical
        impl returns (d, I+1, Tw) per-shard item-major bitmaps (built host-side
        once — the InputFormat step of the job)."""
        n, w = db_masks.shape
        d = self.n_data_shards
        pad = (-n) % d
        if pad:
            db_masks = np.concatenate(
                [db_masks, np.zeros((pad, w), np.uint32)], axis=0)
        if self.impl == "vertical":
            assert n_items is not None, "vertical impl needs n_items"
            self._n_items = n_items
            per = db_masks.shape[0] // d
            shards = np.stack([
                vertical_pack(db_masks[i * per:(i + 1) * per], n_items)
                for i in range(d)])                      # (d, I+1, Tw)
            return jax.device_put(
                shards, NamedSharding(self.mesh, P("data", None, None)))
        return jax.device_put(
            db_masks, NamedSharding(self.mesh, P("data", None)))

    # -- one MapReduce job ----------------------------------------------------

    def _build(self, vertical: bool):
        impl = self.impl
        cand_axis = self.cand_axis
        mesh = self.mesh
        cand_spec = P(cand_axis, None) if cand_axis else P(None, None)
        out_spec = P(cand_axis) if cand_axis else P()

        if vertical:
            def mapper(vdb_local, idx_local):
                local = local_counts_vertical(vdb_local[0], idx_local)
                return jax.lax.psum(local, "data")
            in_specs = (P("data", None, None), cand_spec)
        else:
            def mapper(db_local, cands_local):
                local = local_counts(db_local, cands_local, impl)  # map+combine
                return jax.lax.psum(local, "data")                  # reduce
            in_specs = (P("data", None), cand_spec)

        fn = jax.shard_map(mapper, mesh=mesh, in_specs=in_specs,
                           out_specs=out_spec, check_vma=False)
        return jax.jit(fn)

    def _padded_indices(self, masks: np.ndarray) -> np.ndarray:
        """(C, W) masks (zero rows allowed) → (C, kmax) item ids padded with
        the valid-mask sentinel row (AND identity)."""
        sentinel = self._n_items
        pc = popcount_rows(masks)
        kmax = max(int(pc.max()) if pc.size else 1, 1)
        C = masks.shape[0]
        from .bitset import WORD_BITS
        shifts = np.arange(WORD_BITS, dtype=np.uint32)
        bits = ((masks[:, :, None] >> shifts[None, None, :]) & np.uint32(1))
        bits = bits.reshape(C, -1).astype(bool)
        rows, cols = np.nonzero(bits)
        idx = np.full((C, kmax), sentinel, np.int32)
        starts = np.zeros(C + 1, np.int64)
        np.cumsum(pc, out=starts[1:])
        idx[rows, np.arange(rows.size) - starts[rows]] = cols
        return idx

    def phase_count(self, db_sharded, cands_padded: np.ndarray) -> np.ndarray:
        """Run one MapReduce job: count every candidate over the whole DB.

        ``cands_padded`` rows must already be padded to the runtime block
        multiple (see phases.bucket_pad).  Returns host int64 counts.
        """
        vertical = self.impl == "vertical"
        if vertical:
            payload = jnp.asarray(self._padded_indices(cands_padded))
        else:
            payload = jnp.asarray(cands_padded, dtype=jnp.uint32)
        key = (vertical, db_sharded.shape, payload.shape)
        if key not in self._jitted:
            self._jitted[key] = self._build(vertical)
        if key not in self._shape_cache:
            self._shape_cache.add(key)
            self.stats.compiles += 1
        payload = jax.device_put(
            payload,
            NamedSharding(self.mesh,
                          P(self.cand_axis, None) if self.cand_axis else P(None, None)))
        out = self._jitted[key](db_sharded, payload)
        out = np.asarray(jax.block_until_ready(out))
        self.stats.dispatches += 1
        self.stats.rows_counted += int(cands_padded.shape[0])
        return out.astype(np.int64)
