"""MapReduce runtime on a JAX device mesh.

Hadoop concept → this runtime:

* InputSplit            → equal transaction shards along the ``data`` mesh axis
* Mapper + Combiner     → per-device support-count kernel over the local shard
                          (local sums never leave the device uncombined)
* shuffle + Reducer     → one ``jax.lax.psum`` over the ``data`` axis
* one MapReduce *job*   → one jitted ``shard_map`` dispatch

The runtime tracks dispatch and compile counts: the paper's objective —
minimizing the number of scheduled jobs — maps to minimizing dispatches here,
and re-compiles are the analogue of job setup cost.

Device-resident phase pipeline (DESIGN.md §4): a job can be dispatched

* **fused** — the ``count >= min_count`` filter runs on device inside the
  shard_map'd job, so only a bit-packed keep mask (``C/8`` bytes) plus the
  min-count-filtered int32 counts cross back to the host instead of every
  padded candidate's count;
* **async** — ``phase_count_async`` returns a :class:`CountFuture` and never
  calls ``block_until_ready``; the host keeps generating the next level's
  candidates while the job is in flight (``RuntimeStats.overlap_seconds``
  records that overlap).
"""

from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.kernels.autotune import tuned_blocks

from .counting import local_counts, local_counts_vertical
from .bitset import popcount_rows

IMPLS = ("jnp", "matmul", "pallas", "pallas_interpret",
         "matmul_pallas", "matmul_pallas_interpret",
         "vertical", "vertical_matmul",
         "vertical_pallas", "vertical_pallas_interpret",
         "vertical_matmul_pallas", "vertical_matmul_pallas_interpret")


@dataclasses.dataclass
class RuntimeStats:
    dispatches: int = 0
    compiles: int = 0
    rows_counted: int = 0       # candidates counted across all dispatches
    fused_dispatches: int = 0   # jobs that filtered on device
    overlap_seconds: float = 0.0  # host gen time spent while a job was in flight
    bytes_to_host: int = 0      # result bytes actually fetched from device


def _pack_mask(keep: jax.Array) -> jax.Array:
    """(n,) bool → (ceil(n/32),) uint32, bit ``i%32`` of word ``i//32`` = keep[i]."""
    pad = (-keep.shape[0]) % 32
    if pad:
        keep = jnp.concatenate([keep, jnp.zeros((pad,), keep.dtype)])
    b = keep.reshape(-1, 32).astype(jnp.uint32)
    return (b << jnp.arange(32, dtype=jnp.uint32)[None, :]).sum(
        axis=1, dtype=jnp.uint32)


def _unpack_mask(packed: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`_pack_mask` on host → (n,) bool."""
    bits = np.unpackbits(packed.view(np.uint8), bitorder="little")
    return bits[:n].astype(bool)


class CountFuture:
    """Handle for one in-flight counting job.

    The device arrays are not fetched (and the host never blocks) until
    ``result()`` is called — the double-buffering half of the async pipeline.

    ``result()`` returns host counts ``(C,) int64`` for a plain job, or a
    ``(keep_mask (C,) bool, counts (C,) int64)`` pair for a fused job (counts
    are zeroed where the device filter dropped the candidate; ``None`` when
    the job was dispatched with ``with_counts=False``).
    """

    def __init__(self, runtime: "MapReduceRuntime", raw, *, fused: bool,
                 with_counts: bool, n_rows: int):
        self._rt = runtime
        self._raw = raw
        self._fused = fused
        self._with_counts = with_counts
        self._n = n_rows
        self._result = None
        self.wait_seconds = 0.0   # host time actually blocked in result()

    def ready(self) -> bool:
        """Best-effort non-blocking completion probe."""
        try:
            return all(leaf.is_ready()
                       for leaf in jax.tree_util.tree_leaves(self._raw))
        except AttributeError:      # very old jax.Array without is_ready
            return True

    def result(self):
        if self._result is None:
            t0 = time.perf_counter()
            raw = jax.block_until_ready(self._raw)
            self.wait_seconds = time.perf_counter() - t0
            stats = self._rt.stats
            if self._fused:
                packed = np.asarray(raw[0])
                stats.bytes_to_host += packed.nbytes
                if packed.dtype == np.uint32:      # bit-packed (replicated job)
                    keep = _unpack_mask(packed, self._n)
                else:                              # plain bool (cand-sharded)
                    keep = packed[:self._n].astype(bool)
                counts = None
                if self._with_counts:
                    c = np.asarray(raw[1])
                    stats.bytes_to_host += c.nbytes
                    counts = c[:self._n].astype(np.int64)
                self._result = (keep, counts)
            else:
                c = np.asarray(raw)
                stats.bytes_to_host += c.nbytes
                self._result = c[:self._n].astype(np.int64)
        return self._result


class MapReduceRuntime:
    """Support-counting runtime over a 1-D (or larger) mesh.

    Args:
      mesh: a Mesh containing a ``data`` axis (other axes are unused here but
        allowed, so the production (data, model) mesh can be passed directly).
        Defaults to a 1-D mesh over all local devices.
      impl: counting implementation — any of ``IMPLS`` (popcount families
        "jnp"/"pallas"/"vertical*" plus their bit-plane "matmul" twins,
        DESIGN.md §10), or None/"auto": the cross-family autotune plan
        winner for the database's shape bucket, resolved at
        :meth:`scatter_db` time (static fallback when autotune is off or
        the plan is unavailable: "pallas" on TPU, "vertical" elsewhere).
      cand_axis: optional mesh axis name to additionally shard *candidates*
        over (2-D decomposition; beyond-paper, see DESIGN.md). None replicates
        candidates, matching the paper (every mapper holds the full trie).
      autotune: consult the block-size autotuner when building counting jobs
        (kernels/autotune.py); False pins the static defaults.
    """

    def __init__(self, mesh: Mesh | None = None, impl: str | None = None,
                 cand_axis: str | None = None, autotune: bool = True):
        if mesh is None:
            mesh = make_mesh((len(jax.devices()),), ("data",))
        self._auto_impl = impl is None or impl == "auto"
        if self._auto_impl:
            # static fallback until scatter_db sees the data shape and can
            # consult the cross-family plan — TPU: dense horizontal Pallas
            # kernel; CPU: vertical layout (§Perf iteration M-D)
            impl = "pallas" if jax.default_backend() == "tpu" else "vertical"
        if impl not in IMPLS:
            raise ValueError(f"unknown impl {impl!r}; options: {IMPLS}")
        self.mesh = mesh
        self.impl = impl
        self.cand_axis = cand_axis
        self.autotune = autotune
        self.stats = RuntimeStats()
        self._shape_cache: set = set()
        self._jitted = {}
        self._n_items: int | None = None

    @property
    def n_data_shards(self) -> int:
        return self.mesh.shape["data"]

    @property
    def vertical(self) -> bool:
        return self.impl.startswith("vertical")

    # -- data distribution ---------------------------------------------------

    def scatter_db(self, db_masks: np.ndarray, n_items: int | None = None):
        """Zero-pad rows to the shard multiple and place shards on devices.

        Horizontal impls return the (N, W) row-sharded matrix; the vertical
        impl returns (d, I+1, Tw) per-shard item-major bitmaps (built host-side
        once — the InputFormat step of the job)."""
        from .bitset import vertical_pack
        n, w = db_masks.shape
        if self._auto_impl and self.autotune and n_items is not None:
            # cross-family plan winner at a representative per-phase shape
            # (the cross-check that fixes tuned-but-slower static defaults,
            # DESIGN.md §10); counts are bit-exact across impls, so the
            # mining result is identical whichever family wins
            from repro.kernels.autotune import tuned_plan
            rep_c = min(max(16 * n_items, 256), 4096)
            plan = tuned_plan("count", C=rep_c, T=n, W=w, kmax=4)
            if plan is not None and plan["impl"] in IMPLS:
                self.impl = plan["impl"]
        d = self.n_data_shards
        pad = (-n) % d
        if pad:
            db_masks = np.concatenate(
                [db_masks, np.zeros((pad, w), np.uint32)], axis=0)
        if self.vertical:
            assert n_items is not None, "vertical impl needs n_items"
            self._n_items = n_items
            per = db_masks.shape[0] // d
            shards = np.stack([
                vertical_pack(db_masks[i * per:(i + 1) * per], n_items)
                for i in range(d)])                      # (d, I+1, Tw)
            return jax.device_put(
                shards, NamedSharding(self.mesh, P("data", None, None)))
        return jax.device_put(
            db_masks, NamedSharding(self.mesh, P("data", None)))

    # -- one MapReduce job ----------------------------------------------------

    def _tuned(self, payload_shape, db_shape) -> dict:
        """Autotuned block sizes for one counting job (static at trace time)."""
        from repro.kernels.autotune import DEFAULTS
        if self.vertical:
            kind = self.impl[len("vertical"):].lstrip("_") or "jnp"
            impl_key = "vertical" if kind == "jnp" else f"vertical_{kind}"
            if not self.autotune:
                return dict(DEFAULTS[impl_key])
            C, kmax = payload_shape
            return tuned_blocks(impl_key, C=C, T=db_shape[-1],
                                W=db_shape[-2] // 32 + 1, kmax=kmax)
        if not self.autotune:
            return dict(DEFAULTS[self.impl])
        C, W = payload_shape
        return tuned_blocks(self.impl, C=C, T=db_shape[0], W=W)

    def _build(self, fused: bool, with_counts: bool, payload_shape, db_shape,
               n_valid: int | None = None):
        impl = self.impl
        vertical = self.vertical
        cand_axis = self.cand_axis
        mesh = self.mesh
        cand_spec = P(cand_axis, None) if cand_axis else P(None, None)
        out_spec = P(cand_axis) if cand_axis else P()
        blocks = self._tuned(payload_shape, db_shape)

        if vertical:
            kind = impl[len("vertical"):].lstrip("_") or "jnp"

            def count_local(vdb_local, idx_local):
                return local_counts_vertical(vdb_local[0], idx_local,
                                             impl=kind, **blocks)
            db_spec = P("data", None, None)
        else:
            def count_local(db_local, cands_local):
                return local_counts(db_local, cands_local, impl, **blocks)
            db_spec = P("data", None)

        if fused:
            def mapper(db_local, payload_local, thr):
                local = count_local(db_local, payload_local)  # map + combine
                counts = jax.lax.psum(local, "data")          # reduce
                if n_valid is not None:
                    counts = counts[:n_valid]   # bucket-pad tail never leaves
                keep = counts >= thr                          # filter, fused
                # candidate-sharded jobs return a plain bool mask: per-shard
                # bit-packing pads each shard to a word boundary, which does
                # not concatenate into one contiguous global bitstream
                mask = keep if cand_axis else _pack_mask(keep)
                if with_counts:
                    return mask, jnp.where(keep, counts, 0)
                return (mask,)
            in_specs = (db_spec, cand_spec, P())
            pack_spec = P(cand_axis) if cand_axis else P()
            out_specs = (pack_spec, out_spec) if with_counts else (pack_spec,)
        else:
            def mapper(db_local, payload_local):
                local = count_local(db_local, payload_local)  # map + combine
                return jax.lax.psum(local, "data")            # reduce
            in_specs = (db_spec, cand_spec)
            out_specs = out_spec

        fn = shard_map(mapper, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
        return jax.jit(fn)

    def _padded_indices(self, masks: np.ndarray) -> np.ndarray:
        """(C, W) masks (zero rows allowed) → (C, kmax) item ids padded with
        the valid-mask sentinel row (AND identity)."""
        sentinel = self._n_items
        pc = popcount_rows(masks)
        kmax = max(int(pc.max()) if pc.size else 1, 1)
        C = masks.shape[0]
        from .bitset import WORD_BITS
        shifts = np.arange(WORD_BITS, dtype=np.uint32)
        bits = ((masks[:, :, None] >> shifts[None, None, :]) & np.uint32(1))
        bits = bits.reshape(C, -1).astype(bool)
        rows, cols = np.nonzero(bits)
        idx = np.full((C, kmax), sentinel, np.int32)
        starts = np.zeros(C + 1, np.int64)
        np.cumsum(pc, out=starts[1:])
        idx[rows, np.arange(rows.size) - starts[rows]] = cols
        return idx

    def phase_count_async(self, db_sharded, cands_padded: np.ndarray,
                          min_count: float | None = None,
                          with_counts: bool = True,
                          n_valid: int | None = None) -> CountFuture:
        """Dispatch one MapReduce job without waiting for it.

        ``cands_padded`` rows must already be padded to the runtime block
        multiple (see phases.bucket_pad).  When ``min_count`` is given the job
        is **fused**: the support filter runs on device and only the packed
        keep mask (+ filtered counts unless ``with_counts=False``) is
        transferred when the returned :class:`CountFuture` is consumed —
        sliced on device to ``n_valid`` rows (the real, pre-padding candidate
        count), so the bucket-pad tail never crosses to the host.
        """
        fused = min_count is not None
        if self.vertical:
            payload = jnp.asarray(self._padded_indices(cands_padded))
        else:
            payload = jnp.asarray(cands_padded, dtype=jnp.uint32)
        if not fused or self.cand_axis is not None:
            # unfused keeps the legacy full-padded transfer; candidate-sharded
            # jobs stay shard-symmetric (no per-shard slicing)
            n_valid = None
        n_rows = int(cands_padded.shape[0]) if n_valid is None else int(n_valid)
        key = (fused, with_counts, n_valid, db_sharded.shape, payload.shape)
        if key not in self._jitted:
            self._jitted[key] = self._build(fused, with_counts,
                                            payload.shape, db_sharded.shape,
                                            n_valid=n_valid)
        if key not in self._shape_cache:
            self._shape_cache.add(key)
            self.stats.compiles += 1
        payload = jax.device_put(
            payload,
            NamedSharding(self.mesh,
                          P(self.cand_axis, None) if self.cand_axis else P(None, None)))
        args = (db_sharded, payload)
        if fused:
            # integer threshold: counts are ints, so >= ceil(min_count) is
            # exactly the host-side `counts >= min_count` float comparison
            args += (jnp.int32(math.ceil(min_count)),)
        out = self._jitted[key](*args)
        self.stats.dispatches += 1
        self.stats.rows_counted += int(cands_padded.shape[0])
        if fused:
            self.stats.fused_dispatches += 1
        return CountFuture(self, out, fused=fused, with_counts=with_counts,
                           n_rows=n_rows)

    def phase_count(self, db_sharded, cands_padded: np.ndarray) -> np.ndarray:
        """Synchronous unfused job: host int64 counts for every padded row."""
        return self.phase_count_async(db_sharded, cands_padded).result()

    def phase_count_filtered(self, db_sharded, cands_padded: np.ndarray,
                             min_count: float, with_counts: bool = True,
                             n_valid: int | None = None):
        """Synchronous fused job → ``(keep_mask, filtered_counts_or_None)``."""
        return self.phase_count_async(db_sharded, cands_padded,
                                      min_count=min_count,
                                      with_counts=with_counts,
                                      n_valid=n_valid).result()
