"""MapReduce runtime on a JAX device mesh.

Hadoop concept → this runtime:

* InputSplit            → equal transaction shards along the ``data`` mesh axis
* Mapper + Combiner     → per-device support-count kernel over the local shard
                          (local sums never leave the device uncombined)
* shuffle + Reducer     → one ``jax.lax.psum`` over the ``data`` axis
* one MapReduce *job*   → one jitted ``shard_map`` dispatch

The runtime tracks dispatch and compile counts: the paper's objective —
minimizing the number of scheduled jobs — maps to minimizing dispatches here,
and re-compiles are the analogue of job setup cost.

Device-resident phase pipeline (DESIGN.md §4): a job can be dispatched

* **fused** — the ``count >= min_count`` filter runs on device inside the
  shard_map'd job, so only a bit-packed keep mask (``C/8`` bytes) plus the
  min-count-filtered int32 counts cross back to the host instead of every
  padded candidate's count;
* **async** — ``phase_count_async`` returns a :class:`CountFuture` and never
  calls ``block_until_ready``; the host keeps generating the next level's
  candidates while the job is in flight (``RuntimeStats.overlap_seconds``
  records that overlap).

Cluster-scale meshes (DESIGN.md §11): the runtime accepts a true 2-D
``(data, cand)`` mesh — transaction shards along ``data`` *and* candidate
shards along ``cand`` — counted by the same single shard_map job: each
device counts its candidate shard against its transaction shard, ``psum``
reduces over ``data`` only, and the results stay sharded over ``cand``
(the per-shard keep masks are packed to exact word boundaries so they
concatenate into one global bitstream).  :meth:`MapReduceRuntime.repartition`
rebuilds the mesh as a different ``(n_data, n_cand)`` split of the same
devices between levels and re-scatters the retained database — the elastic
re-layout the per-level cost-model decision drives — and
:meth:`MapReduceRuntime.rescatter` re-places shards from the host copy (the
shard-recovery half of the fault-tolerant retry protocol).
"""

from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.kernels.autotune import tuned_blocks
from repro.obs.metrics import get_registry

from .counting import local_counts, local_counts_vertical
from .bitset import popcount_rows

IMPLS = ("jnp", "matmul", "pallas", "pallas_interpret",
         "matmul_pallas", "matmul_pallas_interpret",
         "vertical", "vertical_matmul",
         "vertical_pallas", "vertical_pallas_interpret",
         "vertical_matmul_pallas", "vertical_matmul_pallas_interpret")


@dataclasses.dataclass
class RuntimeStats:
    dispatches: int = 0
    compiles: int = 0
    rows_counted: int = 0       # candidates counted across all dispatches
    fused_dispatches: int = 0   # jobs that filtered on device
    overlap_seconds: float = 0.0  # host gen time spent while a job was in flight
    bytes_to_host: int = 0      # result bytes actually fetched from device
    repartitions: int = 0       # elastic mesh re-layouts (DESIGN.md §11)
    scatter_seconds: float = 0.0  # host time spent (re-)placing the database

    def __setattr__(self, name, value):
        # Mirror every increment into the process-wide metrics registry
        # (DESIGN.md §13) so `--metrics-out` snapshots see runtime counters
        # without touching the `stats.x += n` call sites.  Positive deltas
        # only: per-runtime stats reset, the registry accumulates.
        prev = getattr(self, name, None)
        if prev is not None:
            delta = value - prev
            if delta > 0:
                get_registry().counter(f"mine.{name}").inc(delta)
        object.__setattr__(self, name, value)


def _pack_mask(keep: jax.Array) -> jax.Array:
    """(n,) bool → (ceil(n/32),) uint32, bit ``i%32`` of word ``i//32`` = keep[i]."""
    pad = (-keep.shape[0]) % 32
    if pad:
        keep = jnp.concatenate([keep, jnp.zeros((pad,), keep.dtype)])
    b = keep.reshape(-1, 32).astype(jnp.uint32)
    return (b << jnp.arange(32, dtype=jnp.uint32)[None, :]).sum(
        axis=1, dtype=jnp.uint32)


def _unpack_mask(packed: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`_pack_mask` on host → (n,) bool."""
    bits = np.unpackbits(packed.view(np.uint8), bitorder="little")
    return bits[:n].astype(bool)


class CountFuture:
    """Handle for one in-flight counting job.

    The device arrays are not fetched (and the host never blocks) until
    ``result()`` is called — the double-buffering half of the async pipeline.

    ``result()`` returns host counts ``(C,) int64`` for a plain job, or a
    ``(keep_mask (C,) bool, counts (C,) int64)`` pair for a fused job (counts
    are zeroed where the device filter dropped the candidate; ``None`` when
    the job was dispatched with ``with_counts=False``).
    """

    def __init__(self, runtime: "MapReduceRuntime", raw, *, fused: bool,
                 with_counts: bool, n_rows: int):
        self._rt = runtime
        self._raw = raw
        self._fused = fused
        self._with_counts = with_counts
        self._n = n_rows
        self._result = None
        self.wait_seconds = 0.0   # host time actually blocked in result()

    def ready(self) -> bool:
        """Best-effort non-blocking completion probe."""
        try:
            return all(leaf.is_ready()
                       for leaf in jax.tree_util.tree_leaves(self._raw))
        except AttributeError:      # very old jax.Array without is_ready
            return True

    def result(self):
        if self._result is None:
            t0 = time.perf_counter()
            raw = jax.block_until_ready(self._raw)
            self.wait_seconds = time.perf_counter() - t0
            stats = self._rt.stats
            if self._fused:
                packed = np.asarray(raw[0])
                stats.bytes_to_host += packed.nbytes
                # always a bit-packed uint32 stream: candidate-sharded jobs
                # pack per shard at exact word boundaries (rows padded to a
                # multiple of 32·n_cand_shards), so the shard concatenation
                # is the global bitstream
                keep = _unpack_mask(packed, self._n)
                counts = None
                if self._with_counts:
                    c = np.asarray(raw[1])
                    stats.bytes_to_host += c.nbytes
                    counts = c[:self._n].astype(np.int64)
                self._result = (keep, counts)
            else:
                c = np.asarray(raw)
                stats.bytes_to_host += c.nbytes
                self._result = c[:self._n].astype(np.int64)
        return self._result


class MapReduceRuntime:
    """Support-counting runtime over a 1-D data mesh or a 2-D (data, cand) mesh.

    Args:
      mesh: a Mesh containing a ``data`` axis (other axes are unused here but
        allowed, so the production (data, model) mesh can be passed directly).
        Defaults to a 1-D mesh over all local devices; pass
        ``launch.mesh.make_mining_mesh(n_data, n_cand)`` for the 2-D
        transaction×candidate decomposition (DESIGN.md §11).
      impl: counting implementation — any of ``IMPLS`` (popcount families
        "jnp"/"pallas"/"vertical*" plus their bit-plane "matmul" twins,
        DESIGN.md §10), or None/"auto": the cross-family autotune plan
        winner for the database's *per-shard* shape bucket, resolved at
        :meth:`scatter_db` time (static fallback when autotune is off or
        the plan is unavailable: "pallas" on TPU, "vertical" elsewhere).
      cand_axis: optional mesh axis name to additionally shard *candidates*
        over (2-D decomposition; beyond-paper, see DESIGN.md §11). None
        replicates candidates, matching the paper (every mapper holds the
        full trie).
      autotune: consult the block-size autotuner when building counting jobs
        (kernels/autotune.py); False pins the static defaults.
    """

    def __init__(self, mesh: Mesh | None = None, impl: str | None = None,
                 cand_axis: str | None = None, autotune: bool = True):
        if mesh is None:
            mesh = make_mesh((len(jax.devices()),), ("data",))
        self._auto_impl = impl is None or impl == "auto"
        if self._auto_impl:
            # static fallback until scatter_db sees the data shape and can
            # consult the cross-family plan — TPU: dense horizontal Pallas
            # kernel; CPU: vertical layout (§Perf iteration M-D)
            impl = "pallas" if jax.default_backend() == "tpu" else "vertical"
        if impl not in IMPLS:
            raise ValueError(f"unknown impl {impl!r}; options: {IMPLS}")
        if cand_axis is not None and cand_axis not in mesh.shape:
            raise ValueError(f"cand_axis {cand_axis!r} not in mesh axes "
                             f"{tuple(mesh.shape)}")
        self.mesh = mesh
        self.impl = impl
        self.cand_axis = cand_axis
        self.autotune = autotune
        self.stats = RuntimeStats()
        self._shape_cache: set = set()
        self._jitted = {}
        self._n_items: int | None = None
        self._db_masks: np.ndarray | None = None  # host copy for re-scatter

    @property
    def n_data_shards(self) -> int:
        return self.mesh.shape["data"]

    @property
    def n_cand_shards(self) -> int:
        return self.mesh.shape[self.cand_axis] if self.cand_axis else 1

    @property
    def mesh_split(self) -> tuple[int, int]:
        """(n_data, n_cand) — the current transaction×candidate split."""
        return (self.n_data_shards, self.n_cand_shards)

    @property
    def vertical(self) -> bool:
        return self.impl.startswith("vertical")

    @property
    def can_repartition(self) -> bool:
        """True when the mesh is runtime-owned (only data/cand-style axes)
        and a database has been scattered, so :meth:`repartition` can
        rebuild the split from the retained host copy."""
        return (self._db_masks is not None
                and set(self.mesh.axis_names) <= {"data", "cand", "model"})

    # -- data distribution ---------------------------------------------------

    def scatter_db(self, db_masks: np.ndarray, n_items: int | None = None):
        """Zero-pad rows to the shard multiple and place shards on devices.

        Horizontal impls return the (N, W) row-sharded matrix; the vertical
        impl returns (d, I+1, Tw) per-shard item-major bitmaps (built host-side
        once — the InputFormat step of the job).  The unpadded host copy is
        retained for :meth:`repartition`/:meth:`rescatter`."""
        self._db_masks = np.asarray(db_masks, dtype=np.uint32)
        if n_items is not None:
            self._n_items = n_items
        return self._scatter_current()

    def _scatter_current(self):
        """(Re-)place the retained database on the current mesh."""
        from .bitset import vertical_pack
        db_masks = self._db_masks
        n, w = db_masks.shape
        t0 = time.perf_counter()
        if self._auto_impl and self.autotune and self._n_items is not None:
            # cross-family plan winner at a representative *per-shard* phase
            # shape — each device counts C/n_cand candidates against
            # T/n_data transactions, so the plan must bucket on the extents
            # a shard actually sees, not the global ones (DESIGN.md §11);
            # counts are bit-exact across impls, so the mining result is
            # identical whichever family wins
            from repro.kernels.autotune import tuned_plan
            rep_c = min(max(16 * self._n_items, 256), 4096)
            plan = tuned_plan("count", C=max(rep_c // self.n_cand_shards, 32),
                              T=max(n // self.n_data_shards, 1), W=w, kmax=4)
            if plan is not None and plan["impl"] in IMPLS:
                self.impl = plan["impl"]
        d = self.n_data_shards
        pad = (-n) % d
        if pad:
            db_masks = np.concatenate(
                [db_masks, np.zeros((pad, w), np.uint32)], axis=0)
        if self.vertical:
            assert self._n_items is not None, "vertical impl needs n_items"
            per = db_masks.shape[0] // d
            shards = np.stack([
                vertical_pack(db_masks[i * per:(i + 1) * per], self._n_items)
                for i in range(d)])                      # (d, I+1, Tw)
            out = jax.device_put(
                shards, NamedSharding(self.mesh, P("data", None, None)))
        else:
            out = jax.device_put(
                db_masks, NamedSharding(self.mesh, P("data", None)))
        self.stats.scatter_seconds += time.perf_counter() - t0
        return out

    def rescatter(self):
        """Re-place shards from the host copy on the *same* mesh — the
        recovery step of the per-phase retry protocol (a failed shard's
        state is rebuilt from the retained database, the analogue of HDFS
        re-reading an input split on task re-execution)."""
        if self._db_masks is None:
            raise RuntimeError("rescatter() requires a prior scatter_db()")
        return self._scatter_current()

    def repartition(self, n_data: int, n_cand: int = 1):
        """Elastically re-layout as an ``(n_data, n_cand)`` split of the same
        devices and re-scatter the retained database (DESIGN.md §11).

        Candidate counts explode between Apriori levels (k=2→3 especially),
        so the best split is per-level, not per-run: the cost-model
        controller prices the next phase's (C, T) extents and calls this
        between levels.  Compiled jobs are cached per (mesh, shape) key, so
        returning to a previously used split never re-compiles.

        Returns the new sharded database handle.
        """
        if not self.can_repartition:
            raise RuntimeError(
                "repartition() needs a scatter_db'd database and a "
                "runtime-owned mesh (axes within data/cand/model)")
        n_dev = self.mesh.size
        if n_data * n_cand != n_dev:
            raise ValueError(f"split {n_data}x{n_cand} != {n_dev} devices")
        if (n_data, n_cand) != self.mesh_split:
            self.mesh = make_mesh((n_data, n_cand), ("data", "cand"))
            self.cand_axis = "cand" if n_cand > 1 else None
            self.stats.repartitions += 1
        return self._scatter_current()

    # -- one MapReduce job ----------------------------------------------------

    def _tuned(self, payload_shape, db_shape) -> dict:
        """Autotuned block sizes for one counting job (static at trace time).

        Tuning keys bucket on *per-shard* extents — C/n_cand candidate rows
        against this device's transaction shard — because that is the shape
        the kernel actually runs at (DESIGN.md §11); the vertical db_shape is
        already per-shard ((d, I+1, Tw_shard))."""
        from repro.kernels.autotune import DEFAULTS
        dc = self.n_cand_shards
        if self.vertical:
            kind = self.impl[len("vertical"):].lstrip("_") or "jnp"
            impl_key = "vertical" if kind == "jnp" else f"vertical_{kind}"
            if not self.autotune:
                return dict(DEFAULTS[impl_key])
            C, kmax = payload_shape
            return tuned_blocks(impl_key, C=max(C // dc, 1), T=db_shape[-1],
                                W=db_shape[-2] // 32 + 1, kmax=kmax)
        if not self.autotune:
            return dict(DEFAULTS[self.impl])
        C, W = payload_shape
        return tuned_blocks(self.impl, C=max(C // dc, 1),
                            T=max(db_shape[0] // self.n_data_shards, 1), W=W)

    def _build(self, fused: bool, with_counts: bool, payload_shape, db_shape,
               n_valid: int | None = None):
        impl = self.impl
        vertical = self.vertical
        cand_axis = self.cand_axis
        mesh = self.mesh
        cand_spec = P(cand_axis, None) if cand_axis else P(None, None)
        out_spec = P(cand_axis) if cand_axis else P()
        blocks = self._tuned(payload_shape, db_shape)

        if vertical:
            kind = impl[len("vertical"):].lstrip("_") or "jnp"

            def count_local(vdb_local, idx_local):
                return local_counts_vertical(vdb_local[0], idx_local,
                                             impl=kind, **blocks)
            db_spec = P("data", None, None)
        else:
            def count_local(db_local, cands_local):
                return local_counts(db_local, cands_local, impl, **blocks)
            db_spec = P("data", None)

        if fused:
            def mapper(db_local, payload_local, thr):
                local = count_local(db_local, payload_local)  # map + combine
                counts = jax.lax.psum(local, "data")          # reduce
                if cand_axis:
                    # shard-symmetric n_valid: every shard keeps its full
                    # (identical) row extent — rows padded to 32·n_cand —
                    # and masks validity from its global row offset, so the
                    # per-shard bit-packed masks land on exact word
                    # boundaries and concatenate into the global bitstream
                    keep = counts >= thr                      # filter, fused
                    if n_valid is not None:
                        per = counts.shape[0]
                        base = jax.lax.axis_index(cand_axis) * per
                        valid = base + jnp.arange(per, dtype=jnp.int32) < n_valid
                        keep = keep & valid
                else:
                    if n_valid is not None:
                        counts = counts[:n_valid]  # pad tail never leaves
                    keep = counts >= thr                      # filter, fused
                mask = _pack_mask(keep)
                if with_counts:
                    return mask, jnp.where(keep, counts, 0)
                return (mask,)
            in_specs = (db_spec, cand_spec, P())
            pack_spec = P(cand_axis) if cand_axis else P()
            out_specs = (pack_spec, out_spec) if with_counts else (pack_spec,)
        else:
            def mapper(db_local, payload_local):
                local = count_local(db_local, payload_local)  # map + combine
                return jax.lax.psum(local, "data")            # reduce
            in_specs = (db_spec, cand_spec)
            out_specs = out_spec

        fn = shard_map(mapper, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
        return jax.jit(fn)

    def _padded_indices(self, masks: np.ndarray) -> np.ndarray:
        """(C, W) masks (zero rows allowed) → (C, kmax) item ids padded with
        the valid-mask sentinel row (AND identity)."""
        sentinel = self._n_items
        pc = popcount_rows(masks)
        kmax = max(int(pc.max()) if pc.size else 1, 1)
        C = masks.shape[0]
        from .bitset import WORD_BITS
        shifts = np.arange(WORD_BITS, dtype=np.uint32)
        bits = ((masks[:, :, None] >> shifts[None, None, :]) & np.uint32(1))
        bits = bits.reshape(C, -1).astype(bool)
        rows, cols = np.nonzero(bits)
        idx = np.full((C, kmax), sentinel, np.int32)
        starts = np.zeros(C + 1, np.int64)
        np.cumsum(pc, out=starts[1:])
        idx[rows, np.arange(rows.size) - starts[rows]] = cols
        return idx

    def phase_count_async(self, db_sharded, cands_padded: np.ndarray,
                          min_count: float | None = None,
                          with_counts: bool = True,
                          n_valid: int | None = None) -> CountFuture:
        """Dispatch one MapReduce job without waiting for it.

        ``cands_padded`` rows must already be padded to the runtime block
        multiple (see phases.bucket_pad).  When ``min_count`` is given the job
        is **fused**: the support filter runs on device and only the packed
        keep mask (+ filtered counts unless ``with_counts=False``) is
        transferred when the returned :class:`CountFuture` is consumed —
        sliced on device to ``n_valid`` rows (the real, pre-padding candidate
        count), so the bucket-pad tail never crosses to the host.
        """
        fused = min_count is not None
        if self.cand_axis is not None:
            # candidate-sharded jobs need rows divisible by the cand shards
            # AND per-shard rows on a 32-row word boundary, so the fused
            # per-shard keep masks bit-pack without intra-shard padding
            mult = 32 * self.n_cand_shards
            pad = (-cands_padded.shape[0]) % mult
            if pad:
                cands_padded = np.concatenate(
                    [cands_padded,
                     np.zeros((pad, cands_padded.shape[1]), np.uint32)])
        if self.vertical:
            payload = jnp.asarray(self._padded_indices(cands_padded))
        else:
            payload = jnp.asarray(cands_padded, dtype=jnp.uint32)
        if not fused:
            # unfused keeps the legacy full-padded transfer
            n_valid = None
        n_rows = int(cands_padded.shape[0]) if n_valid is None else int(n_valid)
        key = (fused, with_counts, n_valid, db_sharded.shape, payload.shape,
               tuple(self.mesh.shape.items()), self.cand_axis, self.impl)
        if key not in self._jitted:
            self._jitted[key] = self._build(fused, with_counts,
                                            payload.shape, db_sharded.shape,
                                            n_valid=n_valid)
        if key not in self._shape_cache:
            self._shape_cache.add(key)
            self.stats.compiles += 1
        payload = jax.device_put(
            payload,
            NamedSharding(self.mesh,
                          P(self.cand_axis, None) if self.cand_axis else P(None, None)))
        args = (db_sharded, payload)
        if fused:
            # integer threshold: counts are ints, so >= ceil(min_count) is
            # exactly the host-side `counts >= min_count` float comparison
            args += (jnp.int32(math.ceil(min_count)),)
        out = self._jitted[key](*args)
        self.stats.dispatches += 1
        self.stats.rows_counted += int(cands_padded.shape[0])
        if fused:
            self.stats.fused_dispatches += 1
        return CountFuture(self, out, fused=fused, with_counts=with_counts,
                           n_rows=n_rows)

    def phase_count(self, db_sharded, cands_padded: np.ndarray) -> np.ndarray:
        """Synchronous unfused job: host int64 counts for every padded row."""
        return self.phase_count_async(db_sharded, cands_padded).result()

    def phase_count_filtered(self, db_sharded, cands_padded: np.ndarray,
                             min_count: float, with_counts: bool = True,
                             n_valid: int | None = None):
        """Synchronous fused job → ``(keep_mask, filtered_counts_or_None)``."""
        return self.phase_count_async(db_sharded, cands_padded,
                                      min_count=min_count,
                                      with_counts=with_counts,
                                      n_valid=n_valid).result()
