"""Core: MapReduce-based Apriori with combined-pass phases (the paper's contribution)."""

from .bitset import pack_itemsets, unpack_itemsets, n_words, singleton_masks
from .drivers import mine, MiningResult
from .mapreduce import MapReduceRuntime
from .policy import ALGORITHMS
from .rules import Rule, RuleSet, generate_rules, generate_ruleset
from .sequential import sequential_apriori

__all__ = [
    "pack_itemsets", "unpack_itemsets", "n_words", "singleton_masks",
    "mine", "MiningResult", "MapReduceRuntime", "ALGORITHMS",
    "Rule", "RuleSet", "generate_rules", "generate_ruleset",
    "sequential_apriori",
]
