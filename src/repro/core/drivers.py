"""Algorithm drivers: SPC, FPC, DPC, VFPC, ETDPC, Optimized-VFPC, Optimized-ETDPC.

``mine()`` is the public entry point.  It runs Job1 (1-itemset counting) and
then the policy-controlled phase loop, mirroring the paper's driver classes.
Per-phase checkpointing makes every driver restartable from the last completed
phase (phases are idempotent — counting is deterministic — the same property
Hadoop's task re-execution relies on).

With ``pipeline=True`` (default) every counting job is fused (device-side
min-support filter, packed mask home transfer) and dispatched asynchronously,
and the host speculatively joins the next level while a job is in flight —
the device-resident phase pipeline of DESIGN.md §4.  ``pipeline=False``
reproduces the legacy synchronous/unfused loop (kept for A/B benchmarking and
equivalence tests).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.obs.metrics import get_registry
from repro.obs.trace import current_tracer

from .bitset import pack_itemsets, singleton_masks, unpack_itemsets
from .mapreduce import MapReduceRuntime
from .phases import PhaseResult, bucket_pad, count_roofline_attrs, run_phase
from .policy import ALGORITHMS, MeasuredPolicy, PhaseStats

# speculate on the next phase's join only when the current level kept at least
# this fraction of its candidates — the wasted-work factor of joining the
# un-filtered level is (|C|/|L|)², so a low survival rate makes the gamble bad
SPEC_SURVIVAL_THRESHOLD = 0.5


@dataclasses.dataclass
class MiningResult:
    algorithm: str
    min_sup: float
    n_txns: int
    n_items: int
    levels: dict                    # k -> (masks (n,W) uint32, counts (n,) int64)
    phases: list                    # list[PhaseResult]
    total_seconds: float
    dispatches: int
    compiles: int
    straggler_events: int = 0
    retries: int = 0                # failed counting jobs recovered by retry
    repartitions: int = 0           # elastic mesh re-layouts this run (§11)
    overlap_seconds: float = 0.0    # host gen time overlapped with counting jobs
    decisions: list = dataclasses.field(default_factory=list)
    # cost-controller telemetry rows for this run (DESIGN.md §9)

    def itemsets(self) -> dict:
        """Friendly view: k -> {sorted item tuple: count}."""
        out = {}
        for k, (masks, counts) in sorted(self.levels.items()):
            if masks.shape[0] == 0:
                continue
            out[k] = dict(zip(unpack_itemsets(masks), (int(c) for c in counts)))
        return out

    @property
    def n_phases(self) -> int:
        return len(self.phases)


def _ckpt_path(d: str) -> str:
    return os.path.join(d, "mining_state.npz")


def _save_ckpt(d: str, algorithm: str, min_sup: float, levels: dict,
               history: list, k_prev: int):
    os.makedirs(d, exist_ok=True)
    payload = {
        "meta": np.frombuffer(json.dumps({
            "algorithm": algorithm, "min_sup": min_sup, "k_prev": k_prev,
            "history": history,
        }).encode(), dtype=np.uint8),
    }
    for k, (masks, counts) in levels.items():
        payload[f"masks_{k}"] = masks
        payload[f"counts_{k}"] = counts
    tmp = os.path.join(d, "mining_state.tmp.npz")
    np.savez(tmp, **payload)
    os.replace(tmp, _ckpt_path(d))


def _load_ckpt(d: str):
    path = _ckpt_path(d)
    if not os.path.exists(path):
        return None
    z = np.load(path)
    meta = json.loads(bytes(z["meta"]).decode())
    levels = {}
    for name in z.files:
        if name.startswith("masks_"):
            k = int(name.split("_")[1])
            levels[k] = (z[name], z[f"counts_{k}"])
    return meta, levels


def mine(transactions=None, *, db_masks: np.ndarray | None = None,
         n_items: int, min_sup: float, algorithm: str = "optimized_vfpc",
         runtime: MapReduceRuntime | None = None, policy_kwargs: dict | None = None,
         checkpoint_dir: str | None = None, resume: bool = True,
         spec_factor: float = 4.0, max_k: int = 64,
         balance_shards_by_width: bool | None = None,
         pipeline: bool = True,
         elastic: bool = True,
         max_retries: int = 2,
         controller=None,
         count_hook=None) -> MiningResult:
    """Mine frequent itemsets with the selected pass-combining algorithm.

    Args:
      transactions: iterable of item-id iterables (alternative: db_masks).
      db_masks: pre-packed (N, W) uint32 transaction bitmasks.
      n_items: item catalog size.
      min_sup: fractional minimum support (0, 1].
      algorithm: one of policy.ALGORITHMS keys.
      runtime: MapReduceRuntime (defaults to all local devices, auto impl).
      checkpoint_dir: if set, per-phase checkpoints are written and ``resume``
        restarts from the last completed phase.
      spec_factor: straggler threshold — a counting job slower than
        spec_factor × the median job time is re-dispatched once (speculative
        re-execution analogue; idempotent by determinism).
      balance_shards_by_width: statically LPT-balance per-shard total
        transaction width before scattering (the paper's InputSplit-sizing
        concern).  Default None = measured policy: the controller enables
        it only when the predicted straggler waste of the skewed contiguous
        split exceeds the calibrated re-pack cost (DESIGN.md §11).
      pipeline: fused + async counting jobs with speculative gen/count overlap
        (DESIGN.md §4); False runs the legacy synchronous unfused loop.
      elastic: per-level mesh repartitioning (DESIGN.md §11) — between
        levels the controller prices the next phase's (C, T) extents under
        every (data, cand) factorization of the devices and re-layouts when
        a different split beats the current one by more than the measured
        re-scatter cost.  No-op on one device or an uncalibrated model.
      max_retries: per-phase fault tolerance — a counting job that raises
        (a lost shard; injected via ``count_hook`` in tests) is re-dispatched
        up to this many times after re-placing the shards from the retained
        host copy.  Phases are idempotent, so the retried result is exact.
      controller: a :class:`repro.costmodel.CostController`.  Every run
        calibrates it from observed job timings (feeding the shared cost
        model); the ``measured`` policy also *decides* from it, and its
        predictions gate speculative-join overlap.  Default: a controller on
        the process-wide shared model (DESIGN.md §9).
      count_hook: test hook — called as ``("phase_start", k)`` before each
        phase and ``("count_dispatch", k)`` after each counting job is
        dispatched; raising from the latter simulates a shard failure and
        exercises the retry protocol.

    Returns: MiningResult.
    """
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}; options: {sorted(ALGORITHMS)}")
    policy_cls, optimized = ALGORITHMS[algorithm]
    policy = policy_cls(**(policy_kwargs or {}))
    runtime = runtime or MapReduceRuntime()
    if controller is None:
        if isinstance(policy, MeasuredPolicy):
            controller = policy.controller
        else:
            from repro.costmodel import CostController
            controller = CostController()
    elif isinstance(policy, MeasuredPolicy):
        policy.controller = controller    # one controller decides AND observes

    if db_masks is None:
        db_masks = pack_itemsets([list(t) for t in transactions], n_items)
    db_masks = np.asarray(db_masks, dtype=np.uint32)
    n_txns = db_masks.shape[0]
    n_words = db_masks.shape[1]
    min_count = min_sup * n_txns
    # calibration context: within this run, job cost varies only with the
    # candidate count — T, W and the mesh split are pinned here (DESIGN.md §9)
    controller.set_count_context(n_txns=n_txns, n_words=n_words,
                                 impl=runtime.impl,
                                 n_data_shards=runtime.n_data_shards,
                                 n_cand_shards=runtime.n_cand_shards)
    if balance_shards_by_width is None and runtime.n_data_shards > 1:
        # measured policy (DESIGN.md §11): pay the host re-pack only when
        # the predicted straggler waste of the skewed split exceeds it
        from repro.data.loader import shard_width_loads
        balance_shards_by_width = controller.should_rebalance(
            shard_width_loads(db_masks, runtime.n_data_shards),
            est_candidates=max(4 * n_items, 256))
    if balance_shards_by_width and runtime.n_data_shards > 1:
        # static straggler mitigation: LPT-balance per-shard total width
        # under the contiguous split (the paper's InputSplit concern, §5.2)
        from repro.data.loader import balance_masks
        t_bal = time.perf_counter()
        with current_tracer().span("mine.rebalance", n_txns=n_txns,
                                   n_shards=runtime.n_data_shards):
            db_masks = balance_masks(db_masks, runtime.n_data_shards)
        controller.observe_rebalance(n_txns, time.perf_counter() - t_bal)

    tracer = current_tracer()
    t_start = time.perf_counter()
    run_span = tracer.span("mine.run", algorithm=algorithm, n_txns=n_txns,
                           n_items=n_items, min_sup=min_sup)
    overlap_start = runtime.stats.overlap_seconds
    repartitions_start = runtime.stats.repartitions
    with tracer.span("mine.scatter", n_txns=n_txns, n_words=n_words):
        db_sharded = runtime.scatter_db(db_masks, n_items=n_items)
    # re-pin: an "auto" runtime may have switched impl at scatter time
    controller.set_count_context(n_txns=n_txns, n_words=n_words,
                                 impl=runtime.impl,
                                 n_data_shards=runtime.n_data_shards,
                                 n_cand_shards=runtime.n_cand_shards)
    decisions_mark = len(controller.decisions)
    retries = 0

    def _with_retry(dispatch):
        # Per-phase fault tolerance (DESIGN.md §11): a counting job that
        # raises (count_hook in tests, a real device loss in production)
        # re-places the shards from the retained host copy and re-dispatches.
        # Phases are idempotent — counting is deterministic, generation is
        # pure — so the retried phase is exact.
        nonlocal db_sharded, retries
        attempt = 0
        while True:
            try:
                return dispatch()
            except Exception:
                if attempt >= max_retries or runtime._db_masks is None:
                    raise
                attempt += 1
                retries += 1
                db_sharded = runtime.rescatter()

    levels: dict = {}
    phases: list[PhaseResult] = []
    history: list = []       # [(n_candidates, n_frequent_last, elapsed), ...]
    straggler_events = 0
    count_times: list[float] = []

    # -- resume ---------------------------------------------------------------
    k_prev = None
    if checkpoint_dir and resume:
        loaded = _load_ckpt(checkpoint_dir)
        if loaded is not None:
            meta, levels = loaded
            if meta["algorithm"] == algorithm and meta["min_sup"] == min_sup:
                history = [tuple(h) for h in meta["history"]]
                k_prev = meta["k_prev"]
                # Replay policy-internal state: one decide() per completed
                # post-Job1 phase, with the stats it saw at the time.
                for j in range(1, len(history)):
                    policy.decide(
                        PhaseStats(*history[j - 1]),
                        PhaseStats(*history[j - 2]) if j >= 2 else None)
            else:
                levels, history, k_prev = {}, [], None

    def _stats(i):
        if i < 0 or i >= len(history):
            return None
        return PhaseStats(*history[i])

    # -- Job1: frequent 1-itemsets (OneItemsetMapper/Combiner/Reducer) --------
    if k_prev is None:
        t0 = time.perf_counter()
        bytes0 = runtime.stats.bytes_to_host
        singles = singleton_masks(n_items)
        job1_span = tracer.span("mine.phase", k_start=1, npass=1)

        def _job1():
            padded = bucket_pad(singles)
            t_c = time.perf_counter()
            cspan = tracer.span(
                "mine.count", k_start=1, npass=1, n_candidates=n_items,
                padded=int(padded.shape[0]), impl=runtime.impl, fused=pipeline)
            try:
                fut = runtime.phase_count_async(
                    db_sharded, padded,
                    min_count=min_count if pipeline else None, n_valid=n_items)
                cspan.event("count.dispatch")
                if count_hook is not None:
                    count_hook("count_dispatch", 1)
                res = fut.result()
            finally:
                t_el = time.perf_counter() - t_c
                if tracer.enabled:
                    cspan.set(count_seconds=t_el, **count_roofline_attrs(
                        runtime, int(padded.shape[0]), n_txns, n_words,
                        1, t_el))
                cspan.close()
            return res if pipeline else res[:n_items]

        if pipeline:
            keep, counts = _with_retry(_job1)
        else:
            counts = _with_retry(_job1)
            keep = counts >= min_count
        levels[1] = (singles[keep], counts[keep])
        el = time.perf_counter() - t0
        job1_span.set(elapsed_seconds=el, n_candidates=n_items,
                      n_frequent=int(keep.sum())).close()
        phases.append(PhaseResult(1, 1, [n_items], 0.0, el, el,
                                  [int(keep.sum())], {1: levels[1]}, True))
        history.append((n_items, int(keep.sum()), el))
        controller.observe_count(
            n_items, el,
            bytes_to_host=runtime.stats.bytes_to_host - bytes0)
        k_prev = 1
        if checkpoint_dir:
            _save_ckpt(checkpoint_dir, algorithm, min_sup, levels, history, k_prev)

    # -- phase loop ------------------------------------------------------------
    pending_spec = None       # SpecJoin over the previous phase's last level
    pending_keep = None       # its keep mask (resolves spec to join(L) exactly)
    # |L|/|C| of the newest counted level — Job1 (or the resumed history tail)
    # seeds the speculation guard
    last_survival = (history[-1][1] / history[-1][0]
                     if history and history[-1][0] else 0.0)
    while k_prev in levels and levels[k_prev][0].shape[0] > 0 and k_prev < max_k:
        prev_frequent = levels[k_prev][0]
        ph_span = tracer.span("mine.phase", k_start=k_prev + 1)
        mode, val = policy.decide(_stats(len(history) - 1), _stats(len(history) - 2))
        kwargs = {}
        if mode == "width":
            kwargs["npass"] = int(val)
        else:  # budget_alpha: ct = alpha * |L_prev last level|
            kwargs["budget"] = float(val) * prev_frequent.shape[0]

        # expected candidate extent of the phase about to run — sizes both
        # the speculation gate and the elastic mesh decision
        est_cands = int(prev_frequent.shape[0] * (
            kwargs["npass"] if "npass" in kwargs else max(val, 1.0)))

        # elastic per-level repartitioning (DESIGN.md §11): candidate counts
        # explode between levels, so re-price the (data, cand) split at each
        # phase's extents and re-layout when the win beats the re-scatter
        if elastic and runtime.mesh.size > 1 and runtime.can_repartition:
            split = controller.choose_mesh(est_cands,
                                           n_devices=runtime.mesh.size,
                                           current=runtime.mesh_split)
            if split is not None and split != runtime.mesh_split:
                t_rp = time.perf_counter()
                with tracer.span("mine.repartition",
                                 n_data=split[0], n_cand=split[1]):
                    db_sharded = runtime.repartition(*split)
                controller.observe_repartition(
                    n_txns, n_words, time.perf_counter() - t_rp)
                controller.set_count_context(
                    n_txns=n_txns, n_words=n_words, impl=runtime.impl,
                    n_data_shards=split[0], n_cand_shards=split[1])

        do_spec = pipeline and last_survival >= SPEC_SURVIVAL_THRESHOLD
        if do_spec:
            # size the overlap from predictions: a count job predicted shorter
            # than the join it would hide is not worth speculating over
            do_spec = controller.should_speculate(est_cands)
        if count_hook is not None:
            count_hook("phase_start", k_prev)
        gen_method = "prefix" if pipeline else "pairwise"
        bytes0 = runtime.stats.bytes_to_host
        res = _with_retry(lambda: run_phase(
            runtime, db_sharded, n_txns, prev_frequent, k_prev,
            min_count, optimized=optimized, fused=pipeline,
            speculate=do_spec, spec=pending_spec,
            prev_keep=pending_keep, gen_method=gen_method,
            count_hook=count_hook, **kwargs))
        # Straggler mitigation: re-dispatch a pathologically slow counting job.
        if count_times and res.count_seconds > spec_factor * float(np.median(count_times)):
            straggler_events += 1
            ph_span.event("straggler.redispatch",
                          count_seconds=res.count_seconds)
            t_re = time.perf_counter()
            # no speculation on the re-dispatch: the first run already did (and
            # counted) it, and a second join would double-book overlap_seconds
            res2 = _with_retry(lambda: run_phase(
                runtime, db_sharded, n_txns, prev_frequent, k_prev,
                min_count, optimized=optimized, fused=pipeline,
                speculate=False, spec=pending_spec,
                prev_keep=pending_keep, gen_method=gen_method, **kwargs))
            res2.spec, res2.last_keep = res.spec, res.last_keep
            if time.perf_counter() - t_re < res.elapsed_seconds:
                res = res2
        count_times.append(res.count_seconds)

        if res.npass == 0:     # no candidates could be generated → done
            ph_span.set(npass=0).close()
            break
        # calibrate on the phase's full cost (minus the speculative join that
        # belongs to the next phase) — the intercept must capture generation
        # and host-sync overhead too, or fusion looks worthless to the model
        controller.observe_count(
            sum(res.candidate_counts),
            max(res.elapsed_seconds - res.spec_seconds, 0.0),
            bytes_to_host=runtime.stats.bytes_to_host - bytes0)
        controller.observe_spec(res.spec_seconds)
        phases.append(res)
        levels.update(res.levels)
        # policies see the phase's own cost: speculative-join time belongs to
        # the *next* phase's generation (which it replaces), so exclude it —
        # otherwise time-threshold policies (DPC/ETDPC) feed back on it
        history.append((sum(res.candidate_counts),
                        res.frequent_counts[-1] if res.frequent_counts else 0,
                        max(res.elapsed_seconds - res.spec_seconds, 0.0)))
        k_prev = res.k_start + res.npass - 1
        pending_spec, pending_keep = res.spec, res.last_keep
        # the spec arrays are only needed until the next phase resolves them;
        # don't let MiningResult.phases pin every phase's join output forever
        res.spec = res.last_keep = None
        last_survival = (res.frequent_counts[-1] / res.candidate_counts[-1]
                         if res.candidate_counts and res.candidate_counts[-1]
                         else 0.0)
        if checkpoint_dir:
            _save_ckpt(checkpoint_dir, algorithm, min_sup, levels, history, k_prev)
        ph_span.set(npass=res.npass,
                    n_candidates=sum(res.candidate_counts),
                    n_frequent=res.frequent_counts[-1],
                    elapsed_seconds=res.elapsed_seconds,
                    overlap_seconds=res.overlap_seconds).close()

    # drop trailing empty levels
    levels = {k: v for k, v in levels.items() if v[0].shape[0] > 0}
    total_seconds = time.perf_counter() - t_start
    run_span.set(total_seconds=total_seconds, phases=len(phases),
                 dispatches=runtime.stats.dispatches,
                 impl=runtime.impl).close()
    get_registry().gauge("mine.total_seconds").set(total_seconds)
    return MiningResult(
        algorithm=algorithm, min_sup=min_sup, n_txns=n_txns, n_items=n_items,
        levels=levels, phases=phases,
        total_seconds=total_seconds,
        dispatches=runtime.stats.dispatches, compiles=runtime.stats.compiles,
        straggler_events=straggler_events,
        retries=retries,
        repartitions=runtime.stats.repartitions - repartitions_start,
        overlap_seconds=runtime.stats.overlap_seconds - overlap_start,
        decisions=controller.decision_rows(decisions_mark))
