"""Bit-packed itemset algebra.

Transactions and candidate itemsets are represented as bitmasks over the item
catalog, packed into ``W = ceil(n_items / 32)`` uint32 words.  This replaces the
paper's prefix-tree (trie): on TPU there is no efficient pointer chasing, and the
trie's role — cheap subset testing of a transaction against many candidates — is
played by a dense, word-parallel ``(c & t) == c`` test that maps onto the VPU.

All host-side helpers are numpy (numpy >= 2.0 provides ``np.bitwise_count``);
device-side equivalents live next to them with a ``j``-prefix and use
``jax.lax.population_count`` / ``jax.lax.clz``.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

WORD_BITS = 32


def n_words(n_items: int) -> int:
    """Number of uint32 words needed for an ``n_items``-wide bitmask."""
    return (n_items + WORD_BITS - 1) // WORD_BITS


def pack_itemsets(itemsets, n_items: int) -> np.ndarray:
    """Pack an iterable of item-index iterables into an ``(N, W)`` uint32 array."""
    W = n_words(n_items)
    out = np.zeros((len(itemsets), W), dtype=np.uint32)
    for row, items in enumerate(itemsets):
        for it in items:
            if not 0 <= it < n_items:
                raise ValueError(f"item {it} out of range [0, {n_items})")
            out[row, it // WORD_BITS] |= np.uint32(1 << (it % WORD_BITS))
    return out


def unpack_itemsets(masks: np.ndarray) -> list[tuple[int, ...]]:
    """Inverse of :func:`pack_itemsets` — sorted item tuples per row."""
    masks = np.asarray(masks, dtype=np.uint32)
    out = []
    for row in masks:
        items = []
        for wi, word in enumerate(row):
            word = int(word)
            while word:
                low = word & -word
                items.append(wi * WORD_BITS + low.bit_length() - 1)
                word ^= low
        out.append(tuple(items))
    return out


def popcount_rows(masks: np.ndarray) -> np.ndarray:
    """Per-row popcount of an ``(N, W)`` uint32 array → ``(N,)`` int32."""
    return np.bitwise_count(np.asarray(masks, dtype=np.uint32)).sum(axis=1).astype(np.int32)


def singleton_masks(n_items: int) -> np.ndarray:
    """``(n_items, W)`` masks with exactly one bit set each (the 1-itemsets)."""
    W = n_words(n_items)
    out = np.zeros((n_items, W), dtype=np.uint32)
    idx = np.arange(n_items)
    out[idx, idx // WORD_BITS] = np.uint32(1) << (idx % WORD_BITS).astype(np.uint32)
    return out


def floor_log2(x: np.ndarray) -> np.ndarray:
    """floor(log2(x)) for positive ints via the float64 exponent field.

    Exact for x < 2^53 (uint32 qualifies); ~3× faster than np.log2 because it
    is a cast + shift + mask instead of a transcendental (§Perf iteration M-A).
    Zeros map to -1023-ish garbage — callers must mask.
    """
    f = x.astype(np.float64)
    return ((f.view(np.uint64) >> np.uint64(52)).astype(np.int64) & 0x7FF) - 1023


def highest_bit_index(masks: np.ndarray) -> np.ndarray:
    """Index of the highest set bit per ``(..., W)`` mask; -1 for empty masks."""
    masks = np.asarray(masks, dtype=np.uint32)
    *lead, W = masks.shape
    hi = np.full(lead, -1, dtype=np.int64)
    for wi in range(W):
        word = masks[..., wi].astype(np.int64)
        nz = word != 0
        if not nz.any():
            continue
        bl = floor_log2(np.where(nz, word, 1))
        hi = np.where(nz, wi * WORD_BITS + bl, hi)
    return hi


def lowest_bit_index(masks: np.ndarray) -> np.ndarray:
    """Index of the lowest set bit per ``(..., W)`` mask; ``W*32 + 1`` sentinel
    for empty masks."""
    masks = np.asarray(masks, dtype=np.uint32)
    *lead, W = masks.shape
    sentinel = W * WORD_BITS + 1
    lo = np.full(lead, sentinel, dtype=np.int64)
    for wi in range(W):
        word = masks[..., wi].astype(np.int64)
        nz = (word != 0) & (lo == sentinel)   # first word with a set bit wins
        if not nz.any():
            continue
        bl = floor_log2(np.where(nz, word & -word, 1))
        lo = np.where(nz, wi * WORD_BITS + bl, lo)
    return lo


# ---------------------------------------------------------------------------
# 64-bit order-independent-ish hashing of masks (host side, for membership).
# Rows are hashed word-by-word with distinct odd multipliers, so the hash is a
# function of the full (ordered) word vector — i.e. of the exact itemset.
# ---------------------------------------------------------------------------

_MULTS = np.array(
    [0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F, 0x165667B1, 0x9E3779B9,
     0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2D, 0x165667C5, 0xA2B2AE3B, 0x37D4EB2F],
    dtype=np.uint64,
)


def hash_rows(masks: np.ndarray) -> np.ndarray:
    """64-bit hash per row of an ``(N, W)`` uint32 array."""
    masks = np.asarray(masks, dtype=np.uint32)
    W = masks.shape[1]
    if W > len(_MULTS):  # extend multipliers deterministically
        reps = -(-W // len(_MULTS))
        mults = np.tile(_MULTS, reps)[:W]
    else:
        mults = _MULTS[:W]
    h = np.zeros(masks.shape[0], dtype=np.uint64)
    for wi in range(W):
        h ^= (masks[:, wi].astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15)) * mults[wi]
        h ^= h >> np.uint64(29)
        h *= np.uint64(0xBF58476D1CE4E5B9)
    h ^= h >> np.uint64(32)
    return h


class MaskIndex:
    """Sorted-hash membership index over a set of masks.

    Hash collisions are resolved exactly: every probe verifies full word
    equality over the run of equal hashes.
    """

    def __init__(self, masks: np.ndarray):
        self.masks = np.asarray(masks, dtype=np.uint32)
        h = hash_rows(self.masks)
        self._order = np.argsort(h, kind="stable")
        self.sorted_hashes = h[self._order]
        self.sorted_masks = self.masks[self._order]

    def __len__(self) -> int:
        return self.masks.shape[0]

    def find(self, queries: np.ndarray) -> np.ndarray:
        """Vectorized exact lookup → (Q,) int64 row index into the original
        ``masks`` array, or -1 where a query mask is absent."""
        queries = np.asarray(queries, dtype=np.uint32)
        out = np.full(queries.shape[0], -1, dtype=np.int64)
        if len(self) == 0 or queries.shape[0] == 0:
            return out
        qh = hash_rows(queries)
        left = np.searchsorted(self.sorted_hashes, qh, side="left")
        pending = np.arange(queries.shape[0])
        offset = 0
        # Walk equal-hash runs; in practice the first probe resolves ~all rows.
        while pending.size:
            pos = left[pending] + offset
            valid = pos < len(self.sorted_hashes)
            vpend = pending[valid]
            vpos = pos[valid]
            same_hash = self.sorted_hashes[vpos] == qh[vpend]
            vpend = vpend[same_hash]
            vpos = vpos[same_hash]
            if vpend.size == 0:
                break
            eq = (self.sorted_masks[vpos] == queries[vpend]).all(axis=1)
            out[vpend[eq]] = self._order[vpos[eq]]
            pending = vpend[~eq]
            offset += 1
        return out

    def contains(self, queries: np.ndarray) -> np.ndarray:
        """Vectorized exact membership test → (Q,) bool."""
        return self.find(queries) >= 0


def vertical_pack(db_masks: np.ndarray, n_items: int) -> np.ndarray:
    """Vertical (item-major) bitmap layout: row i = bitmap of transactions
    containing item i, packed along transactions.

    Returns ``(n_items + 1, Tw)`` uint32, ``Tw = ceil(N/32)``.  The extra last
    row is the **valid-transaction mask** (1 for every real transaction) — it
    doubles as the AND-identity used to pad variable-length candidates.

    support(candidate) = popcount(AND of its item rows) — §Perf iteration M-D
    (the vertical data layout of Jen et al., the paper's related work [15]).
    """
    db_masks = np.asarray(db_masks, dtype=np.uint32)
    n, W = db_masks.shape
    Tw = (n + WORD_BITS - 1) // WORD_BITS
    # expand to a (n_items+1, N) bit matrix (last row = valid mask), then
    # pack along transactions (little bit-order → uint32 view is bit j%32 of
    # word j//32, matching the horizontal convention)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = ((db_masks[:, :, None] >> shifts[None, None, :]) & np.uint32(1))
    bits = bits.reshape(n, W * WORD_BITS)[:, :n_items].astype(np.uint8)
    bits = np.concatenate([bits, np.ones((n, 1), np.uint8)], axis=1)  # valid
    bt = np.ascontiguousarray(bits.T)                 # (n_items+1, N)
    pad = Tw * WORD_BITS - n
    if pad:
        bt = np.concatenate([bt, np.zeros((bt.shape[0], pad), np.uint8)], axis=1)
    packed = np.packbits(bt, axis=1, bitorder="little")
    return np.ascontiguousarray(packed.view(np.uint32))


# ---------------------------------------------------------------------------
# Device-side (jnp) equivalents.
# ---------------------------------------------------------------------------

def jpopcount_rows(masks: jax.Array) -> jax.Array:
    """Per-row popcount on device → (N,) int32."""
    return jax.lax.population_count(masks.astype(jnp.uint32)).astype(jnp.int32).sum(axis=-1)


def junpack_bits(masks: jax.Array) -> jax.Array:
    """Bit-plane unpack on device: ``(..., W) uint32 → (..., W*32) int8``.

    Column ``w*32 + b`` of the output is bit ``b`` of word ``w`` — the same
    little bit-order every packed layout in this repo uses, so
    ``junpack_bits(pack_itemsets(s, n))[:, i]`` is the indicator of item ``i``
    (columns ≥ ``n_items`` are zero).  This is the shared unpack behind the
    matmul counting forms (DESIGN.md §10): containment becomes
    ``count(c, t) = Σ_b c_bits[b]·t_bits[b] == popcount(c)`` and the sum is an
    int8 ``dot_general`` the MXU/tensor cores execute natively.
    """
    m = masks.astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (m[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*m.shape[:-1], m.shape[-1] * WORD_BITS).astype(jnp.int8)


def jpack_bits(bits: jax.Array) -> jax.Array:
    """Inverse of :func:`junpack_bits`: ``(..., B) int8/bool → (..., ceil(B/32))
    uint32`` (B is zero-padded up to the word multiple)."""
    B = bits.shape[-1]
    pad = (-B) % WORD_BITS
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((*bits.shape[:-1], pad), bits.dtype)], axis=-1)
    words = bits.reshape(*bits.shape[:-1], -1, WORD_BITS).astype(jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return (words << shifts).sum(axis=-1).astype(jnp.uint32)


def jsubset_matrix(cands: jax.Array, txns: jax.Array) -> jax.Array:
    """(C, W) x (T, W) → (C, T) bool: candidate ⊆ transaction."""
    c = cands[:, None, :]
    t = txns[None, :, :]
    return jnp.all((c & t) == c, axis=-1)
