"""Association-rule generation from mined frequent itemsets.

Apriori is "the basic algorithm of Association Rule Mining" (paper §1); this
layer completes the pipeline: frequent itemsets → rules  A ⇒ B  with
confidence = sup(A∪B)/sup(A) and lift = conf/ sup(B)-fraction.

Uses the classic Agrawal–Srikant rule-generation recursion: for each frequent
itemset, grow consequents level-wise, pruning a consequent when its rule
fails min_confidence (anti-monotone in the consequent).  All support lookups
hit the bitmask index of the mining result — no database re-scan.
"""

from __future__ import annotations

import dataclasses
from itertools import combinations

import numpy as np

from .bitset import MaskIndex, pack_itemsets
from .drivers import MiningResult


@dataclasses.dataclass(frozen=True)
class Rule:
    antecedent: tuple
    consequent: tuple
    support: float          # fractional support of A∪B
    confidence: float
    lift: float

    def __str__(self):
        a = ",".join(map(str, self.antecedent))
        c = ",".join(map(str, self.consequent))
        return (f"{{{a}}} => {{{c}}} "
                f"(sup={self.support:.3f} conf={self.confidence:.3f} "
                f"lift={self.lift:.2f})")


class _SupportIndex:
    """itemset tuple -> count, built from a MiningResult's levels."""

    def __init__(self, result: MiningResult):
        self.n_txns = result.n_txns
        self._by_k: dict = {}
        for k, (masks, counts) in result.levels.items():
            idx = MaskIndex(masks)
            self._by_k[k] = (idx, {tuple(t): int(c) for t, c in
                                   zip(_unpack(masks), counts)})

    def count(self, itemset: tuple) -> int | None:
        entry = self._by_k.get(len(itemset))
        if entry is None:
            return None
        return entry[1].get(tuple(sorted(itemset)))


def _unpack(masks):
    from .bitset import unpack_itemsets
    return unpack_itemsets(masks)


def generate_rules(result: MiningResult, min_confidence: float = 0.6,
                   max_rules: int | None = None) -> list[Rule]:
    """All rules A ⇒ B (A,B nonempty, disjoint, A∪B frequent) meeting
    ``min_confidence``, sorted by (confidence, lift) descending."""
    sup = _SupportIndex(result)
    n = result.n_txns
    rules: list[Rule] = []

    for k in sorted(result.levels):
        if k < 2:
            continue
        for itemset in _unpack(result.levels[k][0]):
            full_count = sup.count(itemset)
            if not full_count:
                continue
            # level-wise consequent growth with confidence pruning
            consequents = [(c,) for c in itemset]
            while consequents:
                kept = []
                for cons in consequents:
                    ante = tuple(sorted(set(itemset) - set(cons)))
                    if not ante:
                        continue
                    a_count = sup.count(ante)
                    if not a_count:
                        continue
                    conf = full_count / a_count
                    if conf + 1e-12 < min_confidence:
                        continue  # prune: superset consequents only lower conf
                    c_count = sup.count(tuple(sorted(cons)))
                    lift = (conf / (c_count / n)) if c_count else float("inf")
                    rules.append(Rule(ante, tuple(sorted(cons)),
                                      full_count / n, conf, lift))
                    kept.append(cons)
                # grow consequents from survivors (classic ap-genrules)
                nxt = set()
                for a, b in combinations(kept, 2):
                    u = tuple(sorted(set(a) | set(b)))
                    if len(u) == len(a) + 1 and len(u) < len(itemset):
                        nxt.add(u)
                consequents = sorted(nxt)

    rules.sort(key=lambda r: (-r.confidence, -r.lift))
    return rules[:max_rules] if max_rules else rules
