"""Vectorized association-rule generation from mined frequent itemsets.

Apriori is "the basic algorithm of Association Rule Mining" (paper §1); this
layer completes the pipeline: frequent itemsets → rules  A ⇒ B  with
confidence = sup(A∪B)/sup(A), lift = conf / (sup(B)/N) and leverage =
sup(A∪B)/N − sup(A)·sup(B)/N².

Device-resident design (DESIGN.md §7): instead of the classic per-itemset
Agrawal–Srikant recursion, every antecedent/consequent split of a mined level
is enumerated at once as bit-packed ``(R, W)`` uint32 arrays (the same packing
as ``core/bitset.py`` uses for transactions), supports are looked up from the
level tables with the vectorized sorted-hash probe of ``bitset.MaskIndex``,
and confidence/lift/leverage for all enumerated rules are computed in one
jitted device pass — there is no per-rule Python loop anywhere in generation.

The array product is a :class:`RuleSet` — antecedent masks, consequent masks
and metric vectors in rank order — which is exactly what the serving layer
(`serving/rules_engine.py`) loads onto the device.  :func:`generate_rules`
keeps the friendly decoded view (a list of :class:`Rule` tuples) for CLIs,
examples and tests; its float64 metrics are derived from the stored integer
counts so they are bit-identical to a host-side oracle.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .bitset import MaskIndex, WORD_BITS, n_words, unpack_itemsets
from .drivers import MiningResult

# Split enumeration is O(2^k) per level-k itemset; frequent itemsets beyond
# this length indicate a degenerate min_sup rather than a real workload.
MAX_RULE_K = 22


@dataclasses.dataclass(frozen=True)
class Rule:
    antecedent: tuple
    consequent: tuple
    support: float          # fractional support of A∪B
    confidence: float
    lift: float
    leverage: float = 0.0   # sup(A∪B)/N − sup(A)·sup(B)/N²

    def __str__(self):
        a = ",".join(map(str, self.antecedent))
        c = ",".join(map(str, self.consequent))
        return (f"{{{a}}} => {{{c}}} "
                f"(sup={self.support:.3f} conf={self.confidence:.3f} "
                f"lift={self.lift:.2f})")


@dataclasses.dataclass
class RuleSet:
    """Bit-packed, rank-ordered rule arrays — the device-side rule format.

    Rules are sorted by (confidence, lift) descending.  ``confidence``,
    ``lift``, ``leverage`` and ``score`` are the float32 outputs of the jitted
    device metric pass; the integer count columns are kept so exact float64
    metrics can be re-derived on host (``to_rules``).
    """

    n_items: int
    n_txns: int
    ante_masks: np.ndarray      # (R, W) uint32 antecedent bitmasks
    cons_masks: np.ndarray      # (R, W) uint32 consequent bitmasks
    union_counts: np.ndarray    # (R,) int64  sup(A∪B)
    ante_counts: np.ndarray     # (R,) int64  sup(A)
    cons_counts: np.ndarray     # (R,) int64  sup(B)
    confidence: np.ndarray      # (R,) float32
    lift: np.ndarray            # (R,) float32
    leverage: np.ndarray        # (R,) float32
    score: np.ndarray           # (R,) float32 confidence·lift — serving rank key

    def __len__(self) -> int:
        return self.ante_masks.shape[0]

    def exact_metrics(self):
        """Float64 (support, confidence, lift, leverage) from the int counts."""
        n = float(self.n_txns)
        u = self.union_counts.astype(np.float64)
        a = self.ante_counts.astype(np.float64)
        c = self.cons_counts.astype(np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            conf = np.where(a > 0, u / a, 0.0)
            lift = np.where(c > 0, conf * n / c, np.inf)
            lev = u / n - (a / n) * (c / n)
        return u / n, conf, lift, lev

    def to_rules(self, max_rules: int | None = None) -> list[Rule]:
        """Host decode: sorted tuples + exact float64 metrics per rule."""
        r = len(self) if max_rules is None else min(max_rules, len(self))
        sup, conf, lift, lev = self.exact_metrics()
        antes = unpack_itemsets(self.ante_masks[:r])
        conss = unpack_itemsets(self.cons_masks[:r])
        return [Rule(antes[i], conss[i], float(sup[i]), float(conf[i]),
                     float(lift[i]), float(lev[i])) for i in range(r)]


# ---------------------------------------------------------------------------
# Split enumeration (vectorized over itemsets × splits).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _split_table(k: int):
    """All 2^k − 2 nonempty proper antecedent patterns of a k-itemset.

    Returns ``(splits (S, k) bool, sizes (S,) int64)``; cached per k — callers
    must treat the arrays as read-only.
    """
    s = np.arange(1, (1 << k) - 1, dtype=np.uint32)
    bits = ((s[:, None] >> np.arange(k, dtype=np.uint32)[None, :]) & 1).astype(bool)
    return bits, bits.sum(axis=1).astype(np.int64)


def _item_table(masks: np.ndarray, k: int) -> np.ndarray:
    """(N, W) level-k masks → (N, k) int32 sorted item ids per row."""
    N, W = masks.shape
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = ((masks[:, :, None] >> shifts[None, None, :]) & np.uint32(1))
    bits = bits.reshape(N, W * WORD_BITS).astype(bool)
    _, cols = np.nonzero(bits)
    return cols.reshape(N, k).astype(np.int32)


def _iter_splits(masks: np.ndarray, k: int, chunk_words: int = 1 << 22):
    """Enumerate every antecedent of every level-k itemset, bit-packed, in
    bounded chunks.

    Yields ``(ante (n·S, W) uint32, parent (n·S,) intp, a_size (n·S,) int64)``
    with S = 2^k − 2 and ``n`` itemsets per chunk, sized so the
    (chunk, S, k, W) broadcast intermediate stays near ``chunk_words`` words —
    the caller filters each chunk before the next is built, so peak memory
    never scales with the full N·S rule count of a level.
    """
    N, W = masks.shape
    splits, sizes = _split_table(k)
    S = splits.shape[0]
    items = _item_table(masks, k)
    # per-item singleton masks (N, k, W)
    im = np.zeros((N, k, W), np.uint32)
    ridx = np.arange(N)[:, None]
    cidx = np.arange(k)[None, :]
    im[ridx, cidx, items >> 5] = (1 << (items & 31)).astype(np.uint32)

    step = max(1, chunk_words // max(S * k * W, 1))
    for i in range(0, N, step):
        blk = im[i:i + step]                              # (n, k, W)
        sel = np.where(splits[None, :, :, None], blk[:, None, :, :],
                       np.uint32(0))                      # (n, S, k, W)
        ante = np.bitwise_or.reduce(sel, axis=2).reshape(-1, W)
        parent = np.repeat(np.arange(i, i + blk.shape[0]), S)
        yield ante, parent, np.tile(sizes, blk.shape[0])


# ---------------------------------------------------------------------------
# Support lookup: sorted-hash count tables over the mined levels.
# ---------------------------------------------------------------------------

class _CountTables:
    """Lazy per-size (MaskIndex, counts) tables from result.levels."""

    def __init__(self, levels: dict):
        self._levels = levels
        self._cache: dict = {}

    def get(self, size: int):
        if size not in self._cache:
            entry = self._levels.get(size)
            if entry is None or np.asarray(entry[0]).shape[0] == 0:
                self._cache[size] = None
            else:
                self._cache[size] = (MaskIndex(np.asarray(entry[0], np.uint32)),
                                     np.asarray(entry[1], np.int64))
        return self._cache[size]


def _lookup_counts(table, queries: np.ndarray):
    """Vectorized exact count lookup → ``(counts (Q,) int64, found (Q,) bool)``
    via :meth:`bitset.MaskIndex.find`."""
    if table is None or queries.shape[0] == 0:
        return (np.zeros(queries.shape[0], np.int64),
                np.zeros(queries.shape[0], bool))
    index, counts = table
    idx = index.find(queries)
    found = idx >= 0
    return np.where(found, counts[np.maximum(idx, 0)], 0), found


# ---------------------------------------------------------------------------
# Device metric pass.
# ---------------------------------------------------------------------------

@jax.jit
def _rule_metrics(union, ante, cons, n_txns):
    """One jitted pass: confidence, lift, leverage, score for all rules."""
    u = union.astype(jnp.float32)
    a = ante.astype(jnp.float32)
    c = cons.astype(jnp.float32)
    n = n_txns.astype(jnp.float32)
    conf = u / a
    lift = conf * (n / c)          # c == 0 (missing consequent) → inf
    lev = u / n - (a / n) * (c / n)
    return conf, lift, lev, conf * lift


def _empty_ruleset(result: MiningResult) -> RuleSet:
    W = n_words(result.n_items)
    z = np.zeros((0,), np.int64)
    f = np.zeros((0,), np.float32)
    return RuleSet(result.n_items, result.n_txns,
                   np.zeros((0, W), np.uint32), np.zeros((0, W), np.uint32),
                   z, z.copy(), z.copy(), f, f.copy(), f.copy(), f.copy())


def generate_ruleset(result: MiningResult,
                     min_confidence: float = 0.6) -> RuleSet:
    """All rules A ⇒ B (A, B nonempty, disjoint, A∪B frequent) meeting
    ``min_confidence``, as a rank-ordered :class:`RuleSet`.

    The confidence threshold is applied with the exact float64 semantics of
    the sequential oracle (``conf + 1e-12 >= min_confidence``) from the integer
    support counts; the float32 metric vectors come from the jitted device
    pass over the surviving rules.
    """
    tables = _CountTables(result.levels)
    parts: list[tuple] = []

    for k in sorted(result.levels):
        masks, counts = result.levels[k]
        masks = np.asarray(masks, np.uint32)
        counts = np.asarray(counts, np.int64)
        if k < 2 or masks.shape[0] == 0:
            continue
        if k > MAX_RULE_K:
            raise ValueError(
                f"level {k} exceeds MAX_RULE_K={MAX_RULE_K}: "
                f"2^{k} splits per itemset is not a sane rule workload")
        for ante, parent, a_size in _iter_splits(masks, k):
            cons = masks[parent] & ~ante
            union_c = counts[parent]
            a_c = np.zeros(ante.shape[0], np.int64)
            c_c = np.zeros(ante.shape[0], np.int64)
            found = np.zeros(ante.shape[0], bool)
            for a in range(1, k):
                sel = a_size == a
                if not sel.any():
                    continue
                ac, fa = _lookup_counts(tables.get(a), ante[sel])
                cc, _ = _lookup_counts(tables.get(k - a), cons[sel])
                a_c[sel] = ac
                c_c[sel] = cc      # 0 when missing → lift = inf (legacy)
                found[sel] = fa    # antecedent support is required
            ok = found & (a_c > 0)
            with np.errstate(divide="ignore", invalid="ignore"):
                conf = np.where(ok, union_c / np.where(a_c > 0, a_c, 1), 0.0)
            keep = ok & (conf + 1e-12 >= min_confidence)
            if keep.any():
                parts.append((ante[keep], cons[keep], union_c[keep],
                              a_c[keep], c_c[keep]))

    if not parts:
        return _empty_ruleset(result)

    ante = np.concatenate([p[0] for p in parts], axis=0)
    cons = np.concatenate([p[1] for p in parts], axis=0)
    union_c = np.concatenate([p[2] for p in parts])
    a_c = np.concatenate([p[3] for p in parts])
    c_c = np.concatenate([p[4] for p in parts])

    n = float(result.n_txns)
    conf64 = union_c / a_c
    with np.errstate(divide="ignore"):
        lift64 = np.where(c_c > 0, conf64 * n / np.where(c_c > 0, c_c, 1),
                          np.inf)
    order = np.lexsort((-lift64, -conf64))
    ante, cons = ante[order], cons[order]
    union_c, a_c, c_c = union_c[order], a_c[order], c_c[order]

    conf, lift, lev, score = _rule_metrics(
        jnp.asarray(union_c), jnp.asarray(a_c), jnp.asarray(c_c),
        jnp.float32(result.n_txns))
    return RuleSet(result.n_items, result.n_txns, ante, cons,
                   union_c, a_c, c_c,
                   np.asarray(conf), np.asarray(lift), np.asarray(lev),
                   np.asarray(score))


def generate_rules(result: MiningResult, min_confidence: float = 0.6,
                   max_rules: int | None = None) -> list[Rule]:
    """Decoded view of :func:`generate_ruleset`: rules sorted by
    (confidence, lift) descending with exact float64 metrics."""
    return generate_ruleset(result, min_confidence).to_rules(max_rules)
