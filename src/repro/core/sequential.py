"""Pure-Python sequential Apriori — the ground-truth oracle for all tests.

Deliberately simple (tuples + dict counting), independent from the bitmask and
MapReduce paths so that agreement between the two is meaningful evidence.
"""

from __future__ import annotations

from itertools import combinations


def sequential_apriori(transactions, min_sup: float):
    """Mine frequent itemsets.

    Args:
      transactions: iterable of iterables of item ids.
      min_sup: fractional minimum support in (0, 1].

    Returns:
      dict ``k -> {itemset_tuple: count}`` with itemsets as sorted tuples.
    """
    txns = [frozenset(t) for t in transactions]
    n = len(txns)
    min_count = min_sup * n

    counts1: dict[tuple[int, ...], int] = {}
    for t in txns:
        for it in t:
            counts1[(it,)] = counts1.get((it,), 0) + 1
    levels = {1: {s: c for s, c in counts1.items() if c >= min_count}}

    k = 2
    while levels[k - 1]:
        prev = sorted(levels[k - 1])
        prev_set = set(prev)
        # classic join: equal (k-2)-prefix, differing last item
        cands = []
        for i in range(len(prev)):
            for j in range(i + 1, len(prev)):
                a, b = prev[i], prev[j]
                if a[:-1] == b[:-1]:
                    cand = a + (b[-1],) if a[-1] < b[-1] else b + (a[-1],)
                    # prune: every (k-1)-subset must be frequent
                    if all(sub in prev_set for sub in combinations(cand, k - 1)):
                        cands.append(cand)
        counts = {c: 0 for c in cands}
        cand_sets = [(c, frozenset(c)) for c in cands]
        for t in txns:
            for c, cs in cand_sets:
                if cs <= t:
                    counts[c] += 1
        levels[k] = {c: v for c, v in counts.items() if v >= min_count}
        k += 1
    if not levels[max(levels)]:
        del levels[max(levels)]
    return levels
