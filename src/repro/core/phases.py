"""Multi-pass MapReduce phases — the paper's central construct.

A *phase* = candidate generation for one or more consecutive Apriori levels +
**one** counting job over the sharded database (one dispatch, one psum).

``simple`` phases (VFPC/ETDPC, paper §4.1) call ``apriori_gen`` (join + prune)
at every level; ``optimized`` phases (Optimized-VFPC/ETDPC, §4.2) prune only in
the first level and use ``non_apriori_gen`` (join only) afterwards —
skipped-pruning.  Both produce identical frequent itemsets (paper Fig. 1 and
our property tests): un-pruned candidates are false positives that support
counting removes.

XLA adaptation: candidate rows are padded to power-of-two buckets so that each
(bucket, W) counting shape compiles once and is reused (DESIGN.md §2).

Device-resident pipeline (DESIGN.md §4): with ``fused=True`` the min-support
filter runs inside the counting job and only a packed keep mask + filtered
counts return to the host; the job is dispatched **asynchronously**, and while
it is in flight the host speculatively joins the phase's last candidate level
(parent-indexed, see candidates.SpecJoin) so the *next* phase's first
``apriori_gen`` collapses to a pair-filter + prune.  The time spent generating
while a job is in flight is recorded as ``overlap_seconds``.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.obs.trace import current_tracer

from .candidates import (SpecJoin, apriori_gen, non_apriori_gen, prune,
                         speculative_join)
from .mapreduce import MapReduceRuntime

MIN_BUCKET = 256


def _impl_family(impl: str) -> str:
    """Map a runtime impl name to its roofline kernel family."""
    if "matmul" in impl:
        return "matmul"
    if impl.startswith("vertical"):
        return "vertical"
    return "horizontal"


def count_roofline_attrs(runtime: MapReduceRuntime, n_candidates: int,
                         n_txns: int, n_words: int, kmax: int,
                         seconds: float) -> dict:
    """Achieved-vs-peak span attributes for one counting job, computed by
    the same ``roofline.count_kernel_roofline`` that BENCH_kernels.json
    uses — traces and benchmarks report from one set of numbers
    (DESIGN.md §10/§13)."""
    try:
        import jax

        from repro.roofline import count_kernel_roofline
        roof = count_kernel_roofline(
            _impl_family(runtime.impl), C=n_candidates, T=n_txns,
            W=n_words, kmax=kmax, seconds=max(seconds, 1e-9),
            backend=jax.default_backend())
        return {"roofline_bound": roof["bound"],
                "roofline_achieved": roof["achieved"],
                "roofline_peak": roof["peak"],
                "roofline_peak_frac": roof["peak_frac"]}
    except Exception:   # uncalibrated peaks table / exotic backend
        return {}


def bucket_pad(cands: np.ndarray, min_bucket: int = MIN_BUCKET,
               granularity: int = 4096) -> np.ndarray:
    """Zero-pad rows to a bucketed size (compile-cache friendly).

    Small counts use power-of-two buckets (few shapes, cheap);
    large counts use multiples of ``granularity`` — §Perf iteration M-C:
    pow2 buckets pad up to 2× (counting work is proportional to the padded
    size), multiples of 4k bound waste at <4096 rows for a handful more
    compiles.
    """
    n, w = cands.shape
    if n <= granularity:
        b = min_bucket
        while b < n:
            b *= 2
    else:
        b = ((n + granularity - 1) // granularity) * granularity
    out = np.zeros((b, w), dtype=np.uint32)
    out[:n] = cands
    return out


@dataclasses.dataclass
class PhaseResult:
    k_start: int                       # first Apriori level counted in this phase
    npass: int                         # number of levels combined
    candidate_counts: list             # |C_k| per level (as generated)
    gen_seconds: float                 # candidate generation (join [+ prune]) time
    count_seconds: float               # counting job (dispatch + residual wait) time
    elapsed_seconds: float             # total phase wall time
    frequent_counts: list              # |L_k| per level after min_sup filter
    levels: dict                       # k -> (masks (n,W) uint32, counts (n,) int64)
    pruned: bool                       # True if every level pruned (simple phase)
    overlap_seconds: float = 0.0       # host gen overlapped with the in-flight job
    spec_seconds: float = 0.0          # total speculative-join time (next phase's gen)
    spec: SpecJoin | None = None       # speculative join of the last level
    last_keep: np.ndarray | None = None  # keep mask over the last level's candidates


def run_phase(runtime: MapReduceRuntime, db_sharded, n_txns: int,
              prev_frequent: np.ndarray, k_prev: int, min_count: float,
              npass: int | None = None, budget: float | None = None,
              optimized: bool = False, min_bucket: int = MIN_BUCKET,
              fused: bool = True, speculate: bool = False,
              spec: SpecJoin | None = None,
              prev_keep: np.ndarray | None = None,
              gen_method: str = "prefix",
              count_hook=None) -> PhaseResult:
    """Execute one (possibly multi-pass) MapReduce phase.

    Exactly one of ``npass`` (fixed width — SPC/FPC/VFPC style) or ``budget``
    (candidate budget ``ct`` — DPC/ETDPC style: generate levels while the
    cumulative candidate count ≤ ct, always at least one) must be given.

    ``fused`` filters on device (mask + filtered counts come home); plain
    counts otherwise.  ``speculate`` pre-joins the phase's last candidate
    level while the counting job is in flight, returning the result in
    ``PhaseResult.spec`` for the *next* phase; a previous phase's ``spec`` +
    ``prev_keep`` (its keep mask) turn this phase's first join into an exact
    pair-filter (candidates.SpecJoin.resolve).  ``gen_method`` selects the
    join algorithm ("prefix" grouped enumeration vs legacy "pairwise").
    ``count_hook``, if given, is called as ``count_hook("count_dispatch", k)``
    right after the counting job is dispatched — raising from it simulates a
    lost shard mid-job, which the driver's retry protocol recovers from
    (DESIGN.md §11).

    Returns a PhaseResult with per-level frequent itemsets.
    """
    assert (npass is None) != (budget is None), "exactly one of npass/budget"
    tracer = current_tracer()
    t0 = time.perf_counter()
    levels_cands: list[np.ndarray] = []
    cur = prev_frequent
    p, total = 0, 0
    gen_span = tracer.span("mine.gen", k_start=k_prev + 1)
    while True:
        if p == 0 and spec is not None and prev_keep is not None:
            # first-level join precomputed during the previous phase's count
            cands = prune(spec.resolve(prev_keep), prev_frequent, k_prev)
        else:
            gen = apriori_gen if (p == 0 or not optimized) else non_apriori_gen
            cands = gen(cur, k_prev + p, method=gen_method)
        if cands.shape[0] == 0:
            break
        levels_cands.append(cands)
        total += cands.shape[0]
        cur = cands
        p += 1
        if npass is not None and p >= npass:
            break
        if budget is not None and total > budget:
            break
    t_gen = time.perf_counter() - t0
    gen_span.set(n_levels=len(levels_cands), n_candidates=total).close()

    if not levels_cands:
        return PhaseResult(k_prev + 1, 0, [], t_gen, 0.0,
                           time.perf_counter() - t0, [], {}, not optimized)

    all_cands = np.concatenate(levels_cands, axis=0)
    padded = bucket_pad(all_cands, min_bucket)
    t1 = time.perf_counter()
    count_span = tracer.span(
        "mine.count", k_start=k_prev + 1, npass=len(levels_cands),
        n_candidates=int(all_cands.shape[0]), padded=int(padded.shape[0]),
        impl=runtime.impl, fused=fused)
    fut = runtime.phase_count_async(db_sharded, padded,
                                    min_count=min_count if fused else None,
                                    n_valid=all_cands.shape[0])
    count_span.event("count.dispatch")
    if count_hook is not None:
        count_hook("count_dispatch", k_prev + 1)

    # -- overlap window: speculative next-phase join while the job is in flight
    spec_next, t_spec, overlapped = None, 0.0, 0.0
    if speculate:
        in_flight = not fut.ready()
        ts = time.perf_counter()
        with tracer.span("mine.spec_join", k=k_prev + len(levels_cands) + 1,
                         in_flight=in_flight):
            spec_next = speculative_join(levels_cands[-1],
                                         k_prev + len(levels_cands))
        t_spec = time.perf_counter() - ts
        if in_flight:
            # upper bound: the job may complete mid-join; count_seconds below
            # holds the residual wait, so the pair is self-consistent
            overlapped = t_spec
            runtime.stats.overlap_seconds += overlapped

    if fused:
        keep_all, counts_all = fut.result()
    else:
        counts_all = fut.result()
        keep_all = None
    t_count = max(time.perf_counter() - t1 - t_spec, 0.0)
    if tracer.enabled:
        count_span.set(
            count_seconds=t_count, overlap_seconds=overlapped,
            **count_roofline_attrs(
                runtime, int(padded.shape[0]), n_txns, int(padded.shape[1]),
                k_prev + len(levels_cands), t_count))
    count_span.close()

    counts = counts_all[:all_cands.shape[0]]
    levels = {}
    freq_counts = []
    last_keep = None
    off = 0
    for i, cands in enumerate(levels_cands):
        c = counts[off:off + cands.shape[0]]
        if keep_all is not None:
            keep = keep_all[off:off + cands.shape[0]]
        else:
            keep = c >= min_count
        off += cands.shape[0]
        levels[k_prev + 1 + i] = (cands[keep], c[keep])
        freq_counts.append(int(keep.sum()))
        last_keep = keep
    return PhaseResult(
        k_start=k_prev + 1, npass=len(levels_cands),
        candidate_counts=[int(c.shape[0]) for c in levels_cands],
        gen_seconds=t_gen, count_seconds=t_count,
        elapsed_seconds=time.perf_counter() - t0,
        frequent_counts=freq_counts, levels=levels, pruned=not optimized,
        overlap_seconds=overlapped, spec_seconds=t_spec, spec=spec_next,
        last_keep=last_keep)
