"""Multi-pass MapReduce phases — the paper's central construct.

A *phase* = candidate generation for one or more consecutive Apriori levels +
**one** counting job over the sharded database (one dispatch, one psum).

``simple`` phases (VFPC/ETDPC, paper §4.1) call ``apriori_gen`` (join + prune)
at every level; ``optimized`` phases (Optimized-VFPC/ETDPC, §4.2) prune only in
the first level and use ``non_apriori_gen`` (join only) afterwards —
skipped-pruning.  Both produce identical frequent itemsets (paper Fig. 1 and
our property tests): un-pruned candidates are false positives that support
counting removes.

XLA adaptation: candidate rows are padded to power-of-two buckets so that each
(bucket, W) counting shape compiles once and is reused (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from .candidates import apriori_gen, non_apriori_gen
from .mapreduce import MapReduceRuntime

MIN_BUCKET = 256


def bucket_pad(cands: np.ndarray, min_bucket: int = MIN_BUCKET,
               granularity: int = 4096) -> np.ndarray:
    """Zero-pad rows to a bucketed size (compile-cache friendly).

    Small counts use power-of-two buckets (few shapes, cheap);
    large counts use multiples of ``granularity`` — §Perf iteration M-C:
    pow2 buckets pad up to 2× (counting work is proportional to the padded
    size), multiples of 4k bound waste at <4096 rows for a handful more
    compiles.
    """
    n, w = cands.shape
    if n <= granularity:
        b = min_bucket
        while b < n:
            b *= 2
    else:
        b = ((n + granularity - 1) // granularity) * granularity
    out = np.zeros((b, w), dtype=np.uint32)
    out[:n] = cands
    return out


@dataclasses.dataclass
class PhaseResult:
    k_start: int                       # first Apriori level counted in this phase
    npass: int                         # number of levels combined
    candidate_counts: list             # |C_k| per level (as generated)
    gen_seconds: float                 # candidate generation (join [+ prune]) time
    count_seconds: float               # counting job (dispatch) time
    elapsed_seconds: float             # total phase wall time
    frequent_counts: list              # |L_k| per level after min_sup filter
    levels: dict                       # k -> (masks (n,W) uint32, counts (n,) int64)
    pruned: bool                       # True if every level pruned (simple phase)


def run_phase(runtime: MapReduceRuntime, db_sharded, n_txns: int,
              prev_frequent: np.ndarray, k_prev: int, min_count: float,
              npass: int | None = None, budget: float | None = None,
              optimized: bool = False, min_bucket: int = MIN_BUCKET) -> PhaseResult:
    """Execute one (possibly multi-pass) MapReduce phase.

    Exactly one of ``npass`` (fixed width — SPC/FPC/VFPC style) or ``budget``
    (candidate budget ``ct`` — DPC/ETDPC style: generate levels while the
    cumulative candidate count ≤ ct, always at least one) must be given.

    Returns a PhaseResult with per-level frequent itemsets.
    """
    assert (npass is None) != (budget is None), "exactly one of npass/budget"
    t0 = time.perf_counter()
    levels_cands: list[np.ndarray] = []
    cur = prev_frequent
    p, total = 0, 0
    while True:
        gen = apriori_gen if (p == 0 or not optimized) else non_apriori_gen
        cands = gen(cur, k_prev + p)
        if cands.shape[0] == 0:
            break
        levels_cands.append(cands)
        total += cands.shape[0]
        cur = cands
        p += 1
        if npass is not None and p >= npass:
            break
        if budget is not None and total > budget:
            break
    t_gen = time.perf_counter() - t0

    if not levels_cands:
        return PhaseResult(k_prev + 1, 0, [], t_gen, 0.0,
                           time.perf_counter() - t0, [], {}, not optimized)

    all_cands = np.concatenate(levels_cands, axis=0)
    padded = bucket_pad(all_cands, min_bucket)
    t1 = time.perf_counter()
    counts = runtime.phase_count(db_sharded, padded)[:all_cands.shape[0]]
    t_count = time.perf_counter() - t1

    levels = {}
    freq_counts = []
    off = 0
    for i, cands in enumerate(levels_cands):
        c = counts[off:off + cands.shape[0]]
        off += cands.shape[0]
        keep = c >= min_count
        levels[k_prev + 1 + i] = (cands[keep], c[keep])
        freq_counts.append(int(keep.sum()))
    return PhaseResult(
        k_start=k_prev + 1, npass=len(levels_cands),
        candidate_counts=[int(c.shape[0]) for c in levels_cands],
        gen_seconds=t_gen, count_seconds=t_count,
        elapsed_seconds=time.perf_counter() - t0,
        frequent_counts=freq_counts, levels=levels, pruned=not optimized)
