"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh):

  compute_s    = FLOPs / (chips × 197 TFLOP/s bf16)
  memory_s     = HBM bytes / (chips × 819 GB/s)
  collective_s = per-chip communicated bytes / (50 GB/s/link)

Sources and caveats (see EXPERIMENTS.md §Methodology):

* ``collective_bytes`` is parsed from the compiled SPMD HLO: every
  all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
  with ring-algorithm byte multipliers and **while-loop trip-count
  attribution** — XLA's cost_analysis counts a while body once, so each
  computation's contribution is multiplied by its loop trip count (parsed
  from the loop-condition constant), which matters because all layers live
  inside a `lax.scan`.
* compute/memory use exact analytic accounting from the model config
  (6·N·D weight FLOPs (+ attention/SSD terms), parameter+optimizer+activation
  HBM traffic).  Raw ``cost_analysis`` numbers are recorded alongside, with
  the loop-undercount caveat.
* The CPU backend legalizes some bf16 dots to f32, so ``memory_analysis``
  per-device bytes are up to ~2× pessimistic vs TPU for matmul-adjacent
  temporaries; raw values are reported as upper bounds.
"""

from __future__ import annotations

import dataclasses
import re

HW = {
    "peak_flops": 197e12,   # bf16 per chip (TPU v5e-class)
    "hbm_bw": 819e9,        # bytes/s per chip
    "link_bw": 50e9,        # bytes/s per ICI link
}


# -- measured-ops basis for the counting side (DESIGN.md §9) -------------------
#
# The cost-model subsystem (repro/costmodel/) fits per-(device, impl, kind)
# affine models  t ≈ a + b·ops  where ``ops`` is the job's work in this basis.
# The basis is deliberately the *horizontal* §3 form — candidate-word
# comparisons — for every impl: vertical/delta layouts do proportionally less
# word work, but the constant of proportionality is absorbed by the per-key
# slope ``b``, so the affine family is the same and fits never mix bases.
#
# Device→host transfers ride in the same basis: one PCIe byte is priced at
# ``XFER_OPS_PER_BYTE`` candidate-word comparisons (a ~10 GB/s link against a
# compute path that retires hundreds of Gops/s of word tests), so
# impl/fusion decisions see the transfer cost of the result shapes they
# produce, not only the counting work (PR 6 follow-on, DESIGN.md §10).

XFER_OPS_PER_BYTE = 64.0


def count_job_ops(n_candidates: int, n_txns: int, n_words: int = 1,
                  bytes_to_host: float = 0.0) -> float:
    """Work of one support-counting job in the measured-ops basis: C·T·W
    candidate-word comparisons (each of C candidates tested against each of
    T transactions over W mask words), plus the job's device→host result
    traffic priced at ``XFER_OPS_PER_BYTE`` ops per byte."""
    ops = float(max(int(n_candidates), 1)) * max(int(n_txns), 1) * \
        max(int(n_words), 1)
    return ops + max(float(bytes_to_host), 0.0) * XFER_OPS_PER_BYTE


# -- counting-kernel roofline (DESIGN.md §10) ----------------------------------
#
# Per-backend peaks for the achieved-vs-peak fractions BENCH_kernels.json
# records.  The matmul (bit-plane dot_general) forms are compute-bound and
# are compared against the int8 matmul peak; the popcount forms stream words
# and are compared against memory bandwidth.  Figures are nominal
# device-class numbers (TPU v5e-class MXU, A100-class tensor cores, one
# desktop-class CPU socket) — the *fraction* is the methodology artifact, so
# order-of-magnitude peaks are enough to tell "near roofline" from "2% of
# roofline".

COUNT_PEAKS = {
    "cpu": {"int8_ops": 2.0e12, "mem_bw": 50e9},
    "gpu": {"int8_ops": 624e12, "mem_bw": 1550e9},
    "tpu": {"int8_ops": 394e12, "mem_bw": 819e9},
}


def count_kernel_roofline(family: str, *, C: int, T: int, W: int = 1,
                          kmax: int = 1, seconds: float,
                          backend: str) -> dict:
    """Achieved-vs-peak terms for one benched counting-kernel record.

    Args:
      family: "matmul" (bit-plane dot form — any layout), "horizontal"
              (popcount subset scan) or "vertical" (popcount gather-AND).
      C/T/W/kmax: the benched shape (T = transaction rows).
      seconds: measured wall time of one call.
      backend: "cpu" | "gpu" | "tpu".

    Returns a dict with the achieved rate, the peak it is measured against,
    the ``peak_frac`` ratio, and which resource bounds the form.
    """
    peaks = COUNT_PEAKS.get(backend, COUNT_PEAKS["cpu"])
    s = max(float(seconds), 1e-12)
    if family == "matmul":
        # (C, W·32) × (W·32, T) int8 dot: 2 ops (mul+add) per MAC
        macs = float(C) * T * W * 32
        achieved = 2.0 * macs / s
        peak = peaks["int8_ops"]
        bound = "compute"
        unit = "int8_ops_per_s"
    elif family == "vertical":
        # each candidate gathers kmax item rows of T/32 words (4 B each)
        bytes_touched = 4.0 * C * kmax * max(T / 32.0, 1.0)
        achieved = bytes_touched / s
        peak = peaks["mem_bw"]
        bound = "memory"
        unit = "bytes_per_s"
    else:                       # horizontal popcount subset scan
        # word loads for both operands + the (C, T) match matrix traffic
        bytes_touched = 4.0 * W * (float(C) + T) + float(C) * T
        achieved = bytes_touched / s
        peak = peaks["mem_bw"]
        bound = "memory"
        unit = "bytes_per_s"
    return {"family": family, "bound": bound, "unit": unit,
            "achieved": float(achieved), "peak": float(peak),
            "peak_frac": float(achieved / peak)}


def predicted_vs_achieved(predicted_s: float, achieved_s: float) -> dict:
    """One predicted-vs-measured comparison row (cost-model telemetry)."""
    ratio = predicted_s / achieved_s if achieved_s > 0 else float("inf")
    rel_err = (abs(predicted_s - achieved_s) / achieved_s
               if achieved_s > 0 else float("inf"))
    return {"predicted_s": float(predicted_s), "achieved_s": float(achieved_s),
            "ratio": float(ratio), "abs_rel_err": float(rel_err)}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^)]*\)\s*->", re.M)
_CALL_RE = re.compile(
    r"(?:body=|condition=|calls=|to_apply=|branch_computations=\{)\s*%?([\w\.\-]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo: str) -> dict:
    """Split HLO text into {computation_name: body_text}."""
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^)]*\)\s*->.*\{", line)
        if m:
            if cur_name:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name, cur_lines = m.group(1), [line]
        elif cur_name is not None:
            cur_lines.append(line)
            if line.startswith("}"):
                comps[cur_name] = "\n".join(cur_lines)
                cur_name, cur_lines = None, []
    if cur_name:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _trip_count(cond_text: str) -> int:
    """Heuristic scan trip count: the largest s32 constant in the condition."""
    consts = [int(c) for c in re.findall(r"constant\((\d+)\)", cond_text)]
    return max(consts) if consts else 1


def _group_size(line: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def _comm_factor(op: str, g: int) -> float:
    """Per-chip communicated bytes as a multiple of the tensor bytes (ring)."""
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return (g - 1) / g
    if op == "all-reduce":
        return 2 * (g - 1) / g
    if op == "reduce-scatter":
        return (g - 1) / g
    if op == "all-to-all":
        return (g - 1) / g
    if op == "collective-permute":
        return 1.0
    return 1.0


def parse_collectives(hlo: str, n_devices: int) -> dict:
    """Per-chip communicated bytes by collective op, trip-count weighted."""
    comps = _split_computations(hlo)

    # computation multipliers: ENTRY ×1; while bodies × trip count
    mult = {}
    entry = None
    for name in comps:
        if re.search(rf"^ENTRY\s+%?{re.escape(name)}\b", hlo, re.M):
            entry = name
    order = [(entry or next(iter(comps)), 1.0)]
    seen = set()
    while order:
        name, m = order.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        mult[name] = mult.get(name, 0.0) + m
        body = comps[name]
        # while ops: body gets ×trip, condition ×trip
        for wm in re.finditer(
                r"while\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", body):
            cond, wbody = wm.group(1), wm.group(2)
            trip = _trip_count(comps.get(cond, ""))
            order.append((wbody, m * trip))
            order.append((cond, m * trip))
        for cm in _CALL_RE.finditer(body):
            callee = cm.group(1)
            if callee not in seen and not body.count(f"body=%{callee}"):
                order.append((callee, m))

    by_op: dict = {}
    total = 0.0
    for name, m in mult.items():
        for line in comps[name].splitlines():
            cm = _COLL_RE.search(line)
            if not cm:
                continue
            shape_str, op = cm.group(1), cm.group(2)
            b = _shape_bytes(shape_str)
            g = _group_size(line, n_devices)
            comm = b * _comm_factor(op, g) * m
            by_op[op] = by_op.get(op, 0.0) + comm
            total += comm
    return {"by_op": by_op, "per_chip_bytes": total}


# -- analytic FLOPs / bytes ----------------------------------------------------

def analytic_flops(cfg, shape) -> dict:
    """Exact-form FLOP accounting for one step of the given kind."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    weight_flops_fwd = 2 * n_active * tokens

    # attention: 2·S_ctx·hd FLOPs per (token, head) for qk plus same for pv
    hd = cfg.resolved_head_dim
    n_attn_layers = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")
    n_attn_layers += cfg.n_encoder_layers
    if shape.kind == "decode":
        ctx_len = shape.seq_len
        attn_fwd = 4 * ctx_len * cfg.padded_heads * hd * n_attn_layers * shape.global_batch
    else:
        ctx_avg = shape.seq_len / 2
        attn_fwd = 4 * ctx_avg * cfg.padded_heads * hd * n_attn_layers * tokens

    # SSD: per token·head: intra-chunk ≈ 2·L·(N + hd) + state update 2·N·hd
    ssd_fwd = 0
    if cfg.ssm_state:
        from repro.models.ssm import ssm_dims
        d_inner, H, Pd, N = ssm_dims(cfg)
        n_ssm = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "ssm")
        if shape.kind == "decode":
            ssd_fwd = 2 * H * Pd * N * 2 * n_ssm * shape.global_batch
        else:
            L = 256
            ssd_fwd = (2 * L * (N + Pd) + 4 * N * Pd) * H * n_ssm * tokens

    fwd = weight_flops_fwd + attn_fwd + ssd_fwd
    if shape.kind == "train":
        total = 3 * fwd          # bwd ≈ 2× fwd
        # remat recompute: full policy re-runs the forward; "dots" saves
        # matmul outputs and only recomputes elementwise glue (~15%)
        total += fwd if getattr(cfg, "remat_policy", "full") == "full" else 0.15 * fwd
        model_flops = 6 * n_active * tokens
    else:
        total = fwd
        model_flops = 2 * n_active * tokens
    return {"model_flops": float(model_flops), "total_flops": float(total),
            "fwd_flops": float(fwd), "tokens": tokens,
            "params_total": n_total, "params_active": n_active}


def analytic_bytes(cfg, shape, chips: int) -> float:
    """Per-step global HBM traffic (bytes), all chips combined."""
    n = cfg.param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    act_unit = tokens * cfg.d_model * 2  # bf16 residual
    layers = cfg.n_layers + cfg.n_encoder_layers
    if shape.kind == "train":
        # params read (fwd+bwd+remat) ×3, grads written, opt m/v read+write f32,
        # master update; remat-saved activations written+read
        weight_traffic = n * 2 * 3 + n * 2 + 4 * n * 4
        act_traffic = act_unit * layers * (2 + 10)  # saves + working set approx
        return float(weight_traffic + act_traffic)
    if shape.kind == "prefill":
        weight_traffic = n * 2
        act_traffic = act_unit * layers * 6
        return float(weight_traffic + act_traffic)
    # decode: whole weight set + KV cache read per token step
    hd = cfg.resolved_head_dim
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")
    kv_bytes = (2 * shape.seq_len * cfg.n_kv_heads * hd * n_attn
                * shape.global_batch * 2)
    return float(cfg.active_param_count() * 2 + kv_bytes)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_raw: float
    useful_ratio: float

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(cfg, shape, chips: int, collective_per_chip_bytes: float,
                   hlo_flops_raw: float = 0.0) -> RooflineTerms:
    fl = analytic_flops(cfg, shape)
    by = analytic_bytes(cfg, shape, chips)
    compute_s = fl["total_flops"] / (chips * HW["peak_flops"])
    memory_s = by / (chips * HW["hbm_bw"])
    collective_s = collective_per_chip_bytes / HW["link_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = fl["model_flops"] / fl["total_flops"] if fl["total_flops"] else 0.0
    return RooflineTerms(compute_s, memory_s, collective_s, dominant,
                         fl["model_flops"], hlo_flops_raw, useful)
