"""Logical-axis sharding: parameters and activations carry *logical* axis names
(`"embed"`, `"mlp"`, `"vocab"`, ...) which a rules table maps to physical mesh
axes — the MaxText/Flax pattern, without a Flax dependency.

Default production profile (see DESIGN.md §Large-scale runnability):
  * weights: TP on the `model` axis along mlp/head/vocab/expert dims and
    FSDP on the `data` axis along the embed (d_model) dim → per-chip weight
    bytes scale with 1/(data*model).
  * activations: batch on `data`; residual-stream sequence on `model`
    (Megatron-style sequence parallelism) so remat-saved layer boundaries are
    fully sharded.
  * long-context decode: KV-cache sequence on `data` (batch=1 cells).

Rules are overridable per (arch × shape) via the config's sharding profile.
The multi-pod mesh folds the `pod` axis into data parallelism: every rule that
maps to "data" maps to ("pod", "data") when a pod axis is present.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# -- rule tables -------------------------------------------------------------

# logical axis -> physical mesh axis (or None = replicate)
DEFAULT_RULES = {
    "batch": "data",
    "seq": None,            # sequence of *inputs* (token ids) — replicated dims
    "act_seq": "model",     # residual-stream sequence (sequence parallelism)
    "embed": "data",        # FSDP dim of weights
    "mlp": "model",         # TP dim of weights
    "q_heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "experts": "model",     # expert parallelism
    "expert_mlp": None,
    "layers": None,         # scan dim — never sharded
    "kv_seq": None,         # KV cache sequence (decode)
    "cache_batch": "data",
    "conv": None,
    "ssm_state": None,
    "ssm_heads": "model",
    # SSM-block batch: SSD is sequential over seq but embarrassingly parallel
    # over batch — prefer batch sharded over BOTH axes, fall back to data only.
    # (list = fallback candidates, tried in order until divisible + conflict-free)
    "ssm_batch": [("data", "model"), "data"],
}

# long-context decode (global_batch == 1): shard the KV/history over `data`,
# replicate weights over `data` (no per-step FSDP all-gather at batch 1).
LONG_CONTEXT_OVERRIDES = {
    "batch": None,
    "cache_batch": None,
    "kv_seq": ["data", "model"],
    "embed": None,
}

# batched decode (§Perf iteration 1): weights replicated over `data` — serving
# reads every weight each step, so FSDP's per-step all-gather only burns ICI;
# KV-cache *sequence* sharded over `model` (flash-decoding layout) — kv-head
# counts rarely divide the model axis, sequence always does.
DECODE_OVERRIDES = {
    "embed": None,
    "kv_seq": ["model"],
    "kv_heads": None,
}


def make_rules(profile: str = "default") -> dict:
    rules = dict(DEFAULT_RULES)
    if profile == "long_context":
        rules.update(LONG_CONTEXT_OVERRIDES)
    elif profile == "decode":
        rules.update(DECODE_OVERRIDES)
    elif profile != "default":
        raise ValueError(f"unknown sharding profile {profile!r}")
    return rules


def physical_axis(mesh: Mesh, phys):
    """Map a rule target onto the mesh, folding `pod` into data parallelism."""
    if phys is None:
        return None
    if phys == "data" and "pod" in mesh.axis_names:
        return ("pod", "data")
    return phys


def _axis_size(mesh: Mesh, phys) -> int:
    if phys is None:
        return 1
    if isinstance(phys, tuple):
        out = 1
        for p in phys:
            out *= mesh.shape[p]
        return out
    return mesh.shape[phys]


def _flatten_phys(mesh: Mesh, phys):
    """Fold pod into data and flatten nested tuples → tuple of mesh axes."""
    if phys is None:
        return None
    if isinstance(phys, str):
        p = physical_axis(mesh, phys)
        return p if isinstance(p, tuple) else (p,)
    out = []
    for el in phys:
        f = _flatten_phys(mesh, el)
        if f:
            out.extend(f)
    return tuple(out)


def spec_for(mesh: Mesh, logical_axes, rules: dict, shape=None) -> P:
    """Logical axes tuple (may contain None) → PartitionSpec for this mesh.

    * When ``shape`` is given, any dimension not divisible by its mapped mesh
      axis falls back to replication (the production behaviour: e.g. 9 query
      heads cannot TP-shard 16 ways — GSPMD requires divisibility).
    * A rules value may be a LIST of candidates tried in order.
    * A mesh axis already consumed by an earlier dim of the same spec is
      skipped (PartitionSpecs must not repeat axes).
    """
    parts = []
    used: set = set()
    for i, ax in enumerate(logical_axes):
        if ax is None:
            parts.append(None)
            continue
        if ax not in rules:
            raise KeyError(f"logical axis {ax!r} missing from rules")
        rule = rules[ax]
        candidates = rule if isinstance(rule, list) else [rule]
        chosen = None
        for cand in candidates:
            phys = _flatten_phys(mesh, cand)
            if phys is None:
                break
            if any(a in used for a in phys):
                continue
            size = 1
            for a in phys:
                size *= mesh.shape[a]
            if shape is not None and shape[i] % size != 0:
                continue
            chosen = phys
            break
        if chosen is None:
            parts.append(None)
        else:
            used.update(chosen)
            parts.append(chosen[0] if len(chosen) == 1 else chosen)
    return P(*parts)


def sharding_for(mesh: Mesh, logical_axes, rules: dict, shape=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, logical_axes, rules, shape))


def tree_specs(mesh: Mesh, axes_tree, rules: dict, shapes_tree=None):
    """Map an axes tree (same structure as params) to PartitionSpecs."""
    if shapes_tree is None:
        return jax.tree.map(
            lambda axes: spec_for(mesh, axes, rules), axes_tree,
            is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(
        lambda axes, s: spec_for(mesh, axes, rules, s.shape), axes_tree,
        shapes_tree, is_leaf=lambda x: isinstance(x, tuple))


def tree_shardings(mesh: Mesh, axes_tree, rules: dict, shapes_tree=None):
    if shapes_tree is None:
        return jax.tree.map(
            lambda axes: sharding_for(mesh, axes, rules), axes_tree,
            is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(
        lambda axes, s: sharding_for(mesh, axes, rules, s.shape), axes_tree,
        shapes_tree, is_leaf=lambda x: isinstance(x, tuple))


def constrain(x, mesh: Mesh, logical_axes, rules: dict):
    """with_sharding_constraint by logical axes (shape-aware fallback)."""
    return jax.lax.with_sharding_constraint(
        x, sharding_for(mesh, logical_axes, rules, x.shape))
