"""CLI: validate metrics snapshots against the versioned schema.

  PYTHONPATH=src python -m repro.obs.validate metrics.json [more.json ...]

Exit code 0 when every snapshot conforms to the schema version it declares
(DESIGN.md §13); nonzero with per-file error listings otherwise.  CI runs
this on the artifacts emitted by the smoke lane so schema drift fails the
build instead of silently breaking downstream dashboards.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.metrics import validate_snapshot


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate metrics snapshot JSON against the versioned "
                    "schema (DESIGN.md §13)")
    ap.add_argument("paths", nargs="+", help="metrics snapshot JSON file(s)")
    args = ap.parse_args(argv)

    failed = 0
    for path in args.paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: UNREADABLE — {e}")
            failed += 1
            continue
        errs = validate_snapshot(doc)
        if errs:
            failed += 1
            print(f"{path}: INVALID ({len(errs)} error"
                  f"{'s' if len(errs) != 1 else ''})")
            for e in errs:
                print(f"  - {e}")
        else:
            n = (len(doc.get("counters", {})) + len(doc.get("gauges", {}))
                 + len(doc.get("histograms", {})))
            print(f"{path}: ok (schema v{doc['schema_version']}, "
                  f"{n} series)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
