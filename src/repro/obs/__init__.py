"""Observability: unified tracing + metrics across mine → stream → serve
(DESIGN.md §13).

* :mod:`repro.obs.clock` — the injectable-clock contract
  (:class:`MonotonicClock` default, :class:`FakeClock` for tests).
* :mod:`repro.obs.trace` — nested spans with attributes and instant
  events, exported as Chrome-trace-event JSON for ``ui.perfetto.dev``;
  near-zero overhead when disabled (``NULL_TRACER`` fast path).
* :mod:`repro.obs.metrics` — process-wide counter/gauge/histogram
  registry with a versioned snapshot schema; ``python -m
  repro.obs.validate`` checks snapshots in CI.
"""

from repro.obs.clock import FakeClock, MonotonicClock
from repro.obs.metrics import (SCHEMA_VERSION, Registry, get_registry,
                               set_registry, validate_snapshot)
from repro.obs.trace import (NULL_TRACER, NullTracer, Span, Tracer,
                             current_tracer, set_tracer, use_tracer)

__all__ = [
    "FakeClock", "MonotonicClock",
    "SCHEMA_VERSION", "Registry", "get_registry", "set_registry",
    "validate_snapshot",
    "NULL_TRACER", "NullTracer", "Span", "Tracer",
    "current_tracer", "set_tracer", "use_tracer",
]
