"""Process-wide metrics registry with a versioned JSON snapshot schema
(DESIGN.md §13).

Counters, gauges, and fixed-bucket latency histograms, keyed by name +
sorted labels (``serving.latency_ms{tenant=t0}``).  One registry is the
source of truth that ``RuntimeStats`` deltas, serving admission telemetry,
and cost-controller decision counts all feed; ``--metrics-out`` dumps
:meth:`Registry.snapshot`, and ``repro.obs.validate`` checks a snapshot
against the schema in CI.

Schema stability contract: :data:`SCHEMA_VERSION` names the exact field
layout produced by :meth:`Registry.snapshot`.  Changing any field requires
bumping the version — ``tests/test_obs.py`` pins the v1 layout as a golden
test, and :func:`validate_snapshot` rejects unknown versions.
"""

from __future__ import annotations

import bisect
from typing import Optional

__all__ = [
    "SCHEMA_VERSION", "DEFAULT_BUCKETS_MS",
    "Counter", "Gauge", "Histogram", "Registry",
    "get_registry", "set_registry", "validate_snapshot",
]

SCHEMA_VERSION = 1
KNOWN_VERSIONS = (1,)

# Log-spaced latency buckets in ms: 50 µs device dispatches up to multi-second
# mine phases land in distinct buckets; the final +inf bucket is implicit.
DEFAULT_BUCKETS_MS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0,
                      50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 10000.0)

HISTOGRAM_FIELDS = ("buckets", "counts", "count", "sum", "p50", "p99")
TOP_LEVEL_FIELDS = ("schema_version", "counters", "gauges", "histograms")


class Counter:
    """A cumulative value.  ``inc`` accepts negative deltas for net counts
    (e.g. an admitted query later displaced by fair shedding)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """A point-in-time value (last write wins)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram: per-bucket counts plus count/sum, with
    bucket-edge percentile estimates (p50/p99 accurate to bucket width)."""

    __slots__ = ("buckets", "counts", "count", "sum")

    def __init__(self, buckets=DEFAULT_BUCKETS_MS):
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # last = overflow
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v

    def percentile(self, q: float) -> float:
        """Upper edge of the bucket containing quantile ``q`` in [0, 100]
        (overflow bucket reports the observed mean of its tail bound)."""
        if self.count == 0:
            return 0.0
        rank = q / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                if i < len(self.buckets):
                    return self.buckets[i]
                return self.sum / self.count  # overflow: fall back to mean
        return self.buckets[-1]


class Registry:
    """Name+label-keyed store of counters/gauges/histograms.

    The process-wide instance (:func:`get_registry`) backs CLI runs; tests
    and the per-server default in ``OpenLoopServer`` use private instances
    so concurrent servers cannot contaminate each other's fair-shedding
    accounting.
    """

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    @staticmethod
    def _key(name: str, labels: dict) -> str:
        if not labels:
            return name
        inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
        return f"{name}{{{inner}}}"

    def counter(self, name: str, **labels) -> Counter:
        key = self._key(name, labels)
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = self._key(name, labels)
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, buckets=None, **labels) -> Histogram:
        key = self._key(name, labels)
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram(
                buckets if buckets is not None else DEFAULT_BUCKETS_MS)
        return h

    def value(self, name: str, **labels) -> float:
        """Read a counter/gauge value without creating it (0.0 if absent)."""
        key = self._key(name, labels)
        m = self._counters.get(key) or self._gauges.get(key)
        return m.value if m is not None else 0.0

    def snapshot(self) -> dict:
        """The versioned JSON document behind ``--metrics-out``.  Field
        layout is frozen per :data:`SCHEMA_VERSION` — see module docstring."""
        return {
            "schema_version": SCHEMA_VERSION,
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {
                k: {"buckets": list(h.buckets), "counts": list(h.counts),
                    "count": h.count, "sum": h.sum,
                    "p50": h.percentile(50), "p99": h.percentile(99)}
                for k, h in sorted(self._histograms.items())},
        }


def validate_snapshot(doc) -> list:
    """Validate a snapshot document against the versioned schema; returns a
    list of error strings (empty == valid)."""
    errs: list[str] = []
    if not isinstance(doc, dict):
        return [f"snapshot must be a JSON object, got {type(doc).__name__}"]
    for key in TOP_LEVEL_FIELDS:
        if key not in doc:
            errs.append(f"missing top-level field '{key}'")
    extra = set(doc) - set(TOP_LEVEL_FIELDS)
    if extra:
        errs.append(f"unknown top-level fields {sorted(extra)} — "
                    f"bump SCHEMA_VERSION to change the schema")
    if errs:
        return errs
    if doc["schema_version"] not in KNOWN_VERSIONS:
        errs.append(f"unknown schema_version {doc['schema_version']!r} "
                    f"(known: {list(KNOWN_VERSIONS)})")
    for section in ("counters", "gauges"):
        if not isinstance(doc[section], dict):
            errs.append(f"'{section}' must be an object")
            continue
        for k, v in doc[section].items():
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                errs.append(f"{section}[{k!r}] must be numeric, got {v!r}")
    if not isinstance(doc["histograms"], dict):
        errs.append("'histograms' must be an object")
        return errs
    for k, h in doc["histograms"].items():
        if not isinstance(h, dict):
            errs.append(f"histograms[{k!r}] must be an object")
            continue
        if set(h) != set(HISTOGRAM_FIELDS):
            errs.append(
                f"histograms[{k!r}] fields {sorted(h)} != schema v"
                f"{SCHEMA_VERSION} fields {sorted(HISTOGRAM_FIELDS)} — "
                f"bump SCHEMA_VERSION to change the layout")
            continue
        if not isinstance(h["buckets"], list) or not isinstance(
                h["counts"], list):
            errs.append(f"histograms[{k!r}] buckets/counts must be arrays")
            continue
        if len(h["counts"]) != len(h["buckets"]) + 1:
            errs.append(
                f"histograms[{k!r}] needs len(counts) == len(buckets)+1 "
                f"(overflow bucket), got {len(h['counts'])} vs "
                f"{len(h['buckets'])}")
    return errs


_registry = Registry()


def get_registry() -> Registry:
    """The process-wide registry (what ``--metrics-out`` snapshots)."""
    return _registry


def set_registry(reg: Optional[Registry]) -> Registry:
    """Swap the process-wide registry (tests install a fresh one to assert
    on exact deltas); ``None`` installs a new empty registry."""
    global _registry
    _registry = reg if reg is not None else Registry()
    return _registry
