"""Injectable clocks — the one timing contract every layer shares
(DESIGN.md §13).

The paper's elapsed-time-driven pass combining only works if per-phase timing
is trustworthy, and timing is only *testable* if it is injectable.  Two
clocks, one interface (``now() -> float`` seconds):

* :class:`MonotonicClock` — ``time.perf_counter`` (the production default:
  monotonic, unaffected by wall-clock jumps);
* :class:`FakeClock` — manually-advanced virtual time (moved here from
  ``tests/loadgen.py`` so the tracer, ``costmodel.measure.time_once`` and the
  serving :class:`~repro.serving.admission.OpenLoopServer` all accept the
  *same* clock object in deterministic tests — no sleeps anywhere).
"""

from __future__ import annotations

import time


class MonotonicClock:
    """``time.perf_counter`` behind the injectable-clock interface."""

    __slots__ = ()

    def now(self) -> float:
        return time.perf_counter()


class FakeClock:
    """Manually-advanced virtual clock (no sleeps, no wall time)."""

    __slots__ = ("t",)

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t
