"""Nested spans + Chrome-trace-event export (DESIGN.md §13).

One mine→stream→serve run becomes a single timeline that opens in
``ui.perfetto.dev``: spans wrap each mine level (gen/count/spec-join,
repartition, re-scatter), StreamMiner updates and re-mines, and each served
query (admission → queue wait → device dispatch), with cost-controller
decisions attached as instant events carrying predicted-vs-measured
residuals.

Design points:

* **Injectable clock** — ``Tracer(clock=FakeClock())`` gives deterministic
  span trees in tests (exact start/duration assertions, no sleeps);
  production uses :class:`~repro.obs.clock.MonotonicClock`.
* **No-op fast path** — the module-level current tracer defaults to
  :data:`NULL_TRACER`, whose ``span()`` returns one shared ``_NullSpan``
  singleton; call sites pay one function call + an attribute check when
  tracing is off.
* **Virtual-time tracks** — :meth:`Tracer.add_span` records spans with
  caller-supplied start/end (the open-loop server's virtual arrival clock),
  on their own ``tid`` track; the exporter normalizes timestamps *per track*
  so wall-clock and virtual-time tracks both start at 0.
"""

from __future__ import annotations

import contextlib
import json
from typing import Any, Optional

from repro.obs.clock import MonotonicClock

__all__ = [
    "Span", "Tracer", "NullTracer", "NULL_TRACER",
    "current_tracer", "set_tracer", "use_tracer",
]


class Span:
    """A named interval with attributes and attached instant events.

    Acts as its own context manager: ``t0`` is stamped at creation,
    ``t1`` on ``__exit__``/``close``.
    """

    __slots__ = ("name", "tid", "t0", "t1", "attrs", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, tid: str,
                 t0: float, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.tid = tid
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs = attrs

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def event(self, name: str, **attrs) -> None:
        """Attach an instant event at the current clock time, on this
        span's track."""
        self._tracer.event(name, tid=self.tid, **attrs)

    def close(self) -> "Span":
        if self.t1 is None:
            self._tracer._close(self)
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class Tracer:
    """Collects spans + instant events; exports Chrome trace-event JSON."""

    enabled = True

    def __init__(self, clock=None, pid: int = 0):
        self.clock = clock if clock is not None else MonotonicClock()
        self.pid = pid
        self.spans: list[Span] = []
        self.events: list[dict] = []
        self._stack: list[Span] = []

    # -- recording ---------------------------------------------------------
    def span(self, name: str, tid: str = "main", **attrs) -> Span:
        """Open a nested span on the live clock; close via ``with`` or
        ``.close()``."""
        s = Span(self, name, tid, self.clock.now(), attrs)
        self.spans.append(s)
        self._stack.append(s)
        return s

    def _close(self, s: Span) -> None:
        s.t1 = self.clock.now()
        if s in self._stack:            # tolerate out-of-order closes
            self._stack.remove(s)

    def add_span(self, name: str, t0: float, t1: float,
                 tid: str = "virtual", **attrs) -> Span:
        """Record a completed span with caller-supplied times (virtual-time
        tracks: open-loop query lifetimes, device busy intervals)."""
        s = Span(self, name, tid, float(t0), attrs)
        s.t1 = float(t1)
        self.spans.append(s)
        return s

    def event(self, name: str, tid: str = "main",
              args: Optional[dict] = None, **attrs) -> dict:
        """Record an instant event.  ``args`` may be a shared mutable dict —
        the cost controller uses this to backfill ``measured``/``residual``
        after the fact (export reads the final values)."""
        ev = {"name": name, "ts": self.clock.now(), "tid": tid,
              "args": args if args is not None else attrs}
        self.events.append(ev)
        return ev

    def current(self) -> Optional[Span]:
        return self._stack[-1] if self._stack else None

    # -- export ------------------------------------------------------------
    def to_chrome(self) -> dict:
        """Chrome trace-event JSON (object format), loadable in
        ``ui.perfetto.dev`` / ``chrome://tracing``.

        Timestamps are µs, normalized per ``tid`` track so wall-clock and
        virtual-time tracks each start at 0.  Open spans are closed at the
        current clock time.
        """
        now = self.clock.now()
        base: dict[str, float] = {}
        for s in self.spans:
            base[s.tid] = min(base.get(s.tid, s.t0), s.t0)
        for ev in self.events:
            base[ev["tid"]] = min(base.get(ev["tid"], ev["ts"]), ev["ts"])

        tids = {tid: i for i, tid in enumerate(sorted(base))}
        out: list[dict] = []
        for tid, idx in tids.items():
            out.append({"name": "thread_name", "ph": "M", "pid": self.pid,
                        "tid": idx, "args": {"name": tid}})
        for s in self.spans:
            t1 = s.t1 if s.t1 is not None else now
            out.append({
                "name": s.name, "ph": "X", "pid": self.pid,
                "tid": tids[s.tid],
                "ts": (s.t0 - base[s.tid]) * 1e6,
                "dur": (t1 - s.t0) * 1e6,
                "args": _jsonable(s.attrs)})
        for ev in self.events:
            out.append({
                "name": ev["name"], "ph": "i", "s": "t", "pid": self.pid,
                "tid": tids[ev["tid"]],
                "ts": (ev["ts"] - base[ev["tid"]]) * 1e6,
                "args": _jsonable(ev["args"])})
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path: str) -> dict:
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return doc


def _jsonable(d: dict) -> dict:
    """Coerce attr values to JSON-safe scalars (numpy ints/floats appear in
    span attributes; Perfetto rejects NaN-free JSON violations)."""
    out: dict[str, Any] = {}
    for k, v in d.items():
        if v is None or isinstance(v, (bool, str)):
            out[k] = v
        elif isinstance(v, (int, float)):
            out[k] = v
        elif isinstance(v, dict):
            out[k] = _jsonable(v)
        else:
            try:
                out[k] = float(v)
            except (TypeError, ValueError):
                out[k] = str(v)
    return out


class _NullSpan:
    """Shared do-nothing span — the disabled-tracing fast path."""

    __slots__ = ()
    name = tid = ""
    t0 = t1 = duration = 0.0
    attrs: dict = {}

    def set(self, **attrs):
        return self

    def event(self, name, **attrs):
        return None

    def close(self):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every call returns the shared null span."""

    enabled = False
    spans: list = []
    events: list = []

    def span(self, name, tid="main", **attrs):
        return _NULL_SPAN

    def add_span(self, name, t0, t1, tid="virtual", **attrs):
        return _NULL_SPAN

    def event(self, name, tid="main", args=None, **attrs):
        return None

    def current(self):
        return None


NULL_TRACER = NullTracer()

_current: Any = NULL_TRACER


def current_tracer():
    """The process-wide active tracer (``NULL_TRACER`` when tracing is
    off) — call sites grab this instead of threading a tracer argument
    through every layer."""
    return _current


def set_tracer(tracer):
    """Install ``tracer`` (or ``None`` → disable) as the active tracer."""
    global _current
    _current = tracer if tracer is not None else NULL_TRACER
    return _current


@contextlib.contextmanager
def use_tracer(tracer):
    """Scoped ``set_tracer`` — restores the previous tracer on exit."""
    global _current
    prev = _current
    _current = tracer if tracer is not None else NULL_TRACER
    try:
        yield _current
    finally:
        _current = prev
