"""Shared benchmark plumbing."""

from __future__ import annotations

import json
import os
import time

from repro.core import mine
from repro.core.mapreduce import MapReduceRuntime
from repro.data import dataset_by_name

ALGOS = ["spc", "fpc", "dpc", "vfpc", "etdpc", "optimized_vfpc", "optimized_etdpc"]

# scaled-down stand-ins for the paper's three datasets (CPU-sized); min_sup
# chosen so mining reaches ≥5 levels (the multi-pass regime the paper targets)
DATASETS = {
    "c20d10k": {"scale": 0.10, "min_sup": 0.125},
    "chess": {"scale": 0.10, "min_sup": 0.55},
    "mushroom": {"scale": 0.08, "min_sup": 0.31},
}


def load(name: str, scale=None, seed: int = 0):
    return dataset_by_name(name, seed=seed, scale=scale or DATASETS[name]["scale"])


def timed_mine(txns, n_items, min_sup, algorithm, *, reps: int = 1,
               warm: bool = False, runtime: MapReduceRuntime | None = None,
               **kw):
    """Run ``mine`` and time it.

    ``warm=True`` runs once un-timed first (compile caches populated) and then
    reports the best of ``reps`` timed runs on the same runtime — the
    steady-state number used for before/after comparisons.
    """
    runtime = runtime or MapReduceRuntime()
    if warm:
        mine(txns, n_items=n_items, min_sup=min_sup, algorithm=algorithm,
             runtime=runtime, **kw)
    best, res = float("inf"), None
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        res = mine(txns, n_items=n_items, min_sup=min_sup, algorithm=algorithm,
                   runtime=runtime, **kw)
        best = min(best, time.perf_counter() - t0)
    return res, best


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    print()


def write_json(filename: str, payload: dict) -> str:
    """Dump a benchmark record next to the repo root (tracked across PRs)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"# wrote {path}")
    return path
