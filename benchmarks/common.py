"""Shared benchmark plumbing."""

from __future__ import annotations

import time

from repro.core import mine
from repro.core.mapreduce import MapReduceRuntime
from repro.data import dataset_by_name

ALGOS = ["spc", "fpc", "dpc", "vfpc", "etdpc", "optimized_vfpc", "optimized_etdpc"]

# scaled-down stand-ins for the paper's three datasets (CPU-sized); min_sup
# chosen so mining reaches ≥5 levels (the multi-pass regime the paper targets)
DATASETS = {
    "c20d10k": {"scale": 0.10, "min_sup": 0.125},
    "chess": {"scale": 0.10, "min_sup": 0.55},
    "mushroom": {"scale": 0.08, "min_sup": 0.31},
}


def load(name: str, scale=None, seed: int = 0):
    return dataset_by_name(name, seed=seed, scale=scale or DATASETS[name]["scale"])


def timed_mine(txns, n_items, min_sup, algorithm, **kw):
    runtime = MapReduceRuntime()
    t0 = time.perf_counter()
    res = mine(txns, n_items=n_items, min_sup=min_sup, algorithm=algorithm,
               runtime=runtime, **kw)
    wall = time.perf_counter() - t0
    return res, wall


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    print()
