"""Paper Tables 7–9: candidates generated per MapReduce phase, showing the
un-pruned-candidate inflation of the optimized (skipped-pruning) variants."""

from .common import DATASETS, emit, load, timed_mine

ALGOS = ["spc", "vfpc", "optimized_vfpc", "etdpc", "optimized_etdpc"]


def run(fast: bool = False):
    rows = []
    for ds in (["mushroom"] if fast else list(DATASETS)):
        txns, n_items = load(ds)
        sup = DATASETS[ds]["min_sup"]
        totals = {}
        for algo in (["vfpc", "optimized_vfpc"] if fast else ALGOS):
            res, wall = timed_mine(txns, n_items, sup, algo)
            per_phase = ";".join(
                f"k{p.k_start}+{p.npass}:" + "/".join(map(str, p.candidate_counts))
                for p in res.phases)
            tot = sum(sum(p.candidate_counts) for p in res.phases)
            totals[algo] = tot
            rows.append((f"tbl_cands/{ds}/{algo}",
                         round(wall * 1e6 / max(tot, 1), 2),
                         f"total_cands={tot} [{per_phase}]"))
        if "vfpc" in totals and "optimized_vfpc" in totals:
            infl = totals["optimized_vfpc"] / max(totals["vfpc"], 1)
            rows.append((f"tbl_cands/{ds}/unpruned_inflation", 0,
                         f"optimized/plain={infl:.3f}x"))
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
