"""Cost-model benchmark (DESIGN.md §9): predictor accuracy + the measured
policy vs the paper's hand-tuned drivers.

Writes ``BENCH_costmodel.json`` with two arms, tracked across PRs by CI:

* ``predictor``  — calibrate the count-job fit on one mining run, then replay
  a *held-out* run (same dataset, different min_sup ⇒ different candidate
  trajectory) predicting every job's time before observing it;
  ``roofline.predicted_vs_achieved`` rows + mean |rel err|.
* ``e2e``        — steady-state mining wall time of ``measured`` (calibrated
  during the warm-up run) against the paper's best hand-tuned arms
  (``optimized_vfpc`` / ``optimized_etdpc``) on the paper datasets; the
  headline is ``measured_within`` = measured ÷ best paper arm.
"""

import jax

from repro.core.mapreduce import MapReduceRuntime
from repro.costmodel import CostController, CostModel
from repro.roofline import predicted_vs_achieved

from .common import DATASETS, emit, load, timed_mine, write_json

PAPER_ARMS = ["optimized_vfpc", "optimized_etdpc"]


class _EvalController(CostController):
    """Predict each counting job's time *before* observing it — the held-out
    prediction-error probe (observation order makes the eval honest)."""

    def __init__(self, model):
        super().__init__(model)
        self.rows = []

    def observe_count(self, n_candidates, seconds, bytes_to_host=None):
        p = self.predict_count(n_candidates, bytes_to_host)
        if p is not None and seconds > 0:
            self.rows.append(dict(n_candidates=int(n_candidates),
                                  **predicted_vs_achieved(p, seconds)))
        super().observe_count(n_candidates, seconds, bytes_to_host)


def _predictor_arm(fast: bool):
    name = "mushroom"
    txns, n_items = load(name)
    min_sup = DATASETS[name]["min_sup"]
    # held-out pass: lower min_sup ⇒ candidate counts the fit never saw
    held_out_sup = min_sup * 0.8
    runtime = MapReduceRuntime()
    # warm both configurations: the model predicts steady-state job cost, so
    # neither the calibration nor the eval pass may pay one-off compiles
    warm = CostController(CostModel(persist=False))
    timed_mine(txns, n_items, min_sup, "optimized_etdpc",
               runtime=runtime, controller=warm)
    timed_mine(txns, n_items, held_out_sup, "optimized_etdpc",
               runtime=runtime, controller=warm)
    ctl = _EvalController(CostModel(persist=False))
    timed_mine(txns, n_items, min_sup, "optimized_etdpc",
               runtime=runtime, controller=ctl)
    calibration_rows = ctl.model.n_samples(ctl.count_key)
    ctl.rows = []
    timed_mine(txns, n_items, held_out_sup, "optimized_etdpc",
               runtime=runtime, controller=ctl)
    errs = [r["abs_rel_err"] for r in ctl.rows]
    return {
        "dataset": name, "held_out_min_sup": round(held_out_sup, 4),
        "calibration_jobs": calibration_rows,
        "held_out_jobs": len(errs),
        "mean_abs_rel_err": round(sum(errs) / len(errs), 4) if errs else None,
        "rows": [{k: (round(v, 6) if isinstance(v, float) else v)
                  for k, v in r.items()} for r in ctl.rows],
    }


def _e2e_arm(fast: bool):
    names = ["mushroom"] if fast else list(DATASETS)
    reps = 3 if fast else 5
    out = {}
    rows = []
    for name in names:
        txns, n_items = load(name)
        min_sup = DATASETS[name]["min_sup"]
        runtime = MapReduceRuntime()
        times = {}
        for algo in PAPER_ARMS:
            _, t = timed_mine(txns, n_items, min_sup, algo, warm=True,
                              reps=reps, runtime=runtime)
            times[algo] = t
        # measured: width ceiling 8 matches the range VFPC's 2,5,8 schedule
        # actually explores.  Calibrate on a throwaway run first — the
        # calibrated model picks different widths (different fused shapes)
        # than the uncalibrated fallback, so the warm run inside timed_mine
        # must already be decision-stable to compile what the reps execute.
        ctl = CostController(CostModel(persist=False), max_width=8)
        timed_mine(txns, n_items, min_sup, "measured", runtime=runtime,
                   controller=ctl)
        _, t = timed_mine(txns, n_items, min_sup, "measured", warm=True,
                          reps=reps, runtime=runtime, controller=ctl)
        times["measured"] = t
        best_paper = min(times[a] for a in PAPER_ARMS)
        out[name] = {
            "seconds": {a: round(v, 4) for a, v in times.items()},
            "best_paper_arm": min(PAPER_ARMS, key=times.get),
            "measured_within": round(times["measured"] / best_paper, 3),
            "decisions": len(ctl.decisions),
        }
        for a, v in times.items():
            rows.append((f"{name}/{a}", f"{v * 1e6:.0f}",
                         f"x{v / best_paper:.2f}"))
    emit(rows, ["name", "us_per_call", "derived"])
    return out


def run(fast: bool = False):
    record = {"backend": jax.default_backend()}
    record["predictor"] = _predictor_arm(fast)
    emit([("costmodel/predictor_err",
           f"{record['predictor']['mean_abs_rel_err']}",
           f"jobs={record['predictor']['held_out_jobs']}")],
         ["name", "us_per_call", "derived"])
    record["e2e"] = _e2e_arm(fast)
    worst = max(v["measured_within"] for v in record["e2e"].values())
    record["headline"] = {
        "mean_abs_rel_err": record["predictor"]["mean_abs_rel_err"],
        "worst_measured_within": worst,
    }
    write_json("BENCH_costmodel.json", record)


if __name__ == "__main__":
    run(fast=True)
