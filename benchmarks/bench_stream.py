"""Streaming subsystem benchmark (DESIGN.md §6/§8): steady-state update
throughput, O(delta) counting vs full recount, and live rule-refresh latency.

Writes ``BENCH_stream.json``: updates/s and per-update latency percentiles
for a sliding-window stream in micro-batches, the measured speedup of one
signed delta-counting dispatch over a full device-resident recount of the
same tracked candidates, and p50/p99 of the RuleSet regeneration + atomic
engine swap — tracked across PRs by CI.
"""

import collections
import time

import jax
import numpy as np

from repro.data import dataset_by_name
from repro.kernels import delta_count, support_count
from repro.stream import StreamMiner

from .common import emit, write_json

MIN_SUP = 0.4


def _best_of(fn, reps=3):
    best = float("inf")
    fn()                                   # warm-up (compile)
    for _ in range(reps):
        t0 = time.perf_counter()
        np.asarray(fn())                   # sync to host
        best = min(best, time.perf_counter() - t0)
    return best


def run(fast: bool = False):
    rows = []
    record = {"backend": jax.default_backend()}
    scale = 0.12 if fast else 0.3
    capacity = 512 if fast else 1024
    batch = 16
    n_updates = 24 if fast else 64
    txns, n_items = dataset_by_name("mushroom", scale=scale)

    miner = StreamMiner(n_items, MIN_SUP, capacity=capacity, mode="sliding")
    fill = min(len(txns), capacity)
    rec0 = miner.push(txns[:fill])
    record["prefill"] = {
        "window": rec0.window_size, "n_frequent": rec0.n_frequent,
        "n_rules": rec0.n_rules, "seconds": round(rec0.update_seconds, 3),
    }

    # -- steady-state streaming updates ---------------------------------------
    paths: collections.Counter = collections.Counter()
    t0 = time.perf_counter()
    for u in range(n_updates):
        lo = (fill + u * batch) % max(len(txns) - batch, 1)
        paths[miner.push(txns[lo:lo + batch]).path] += 1
    total = time.perf_counter() - t0

    ups = miner.updates[1:]
    upd_ms = np.array([r.update_seconds * 1e3 for r in ups])
    refresh_ms = np.array([r.refresh_seconds * 1e3 for r in ups
                           if r.levels_changed])
    record["updates"] = {
        "n_updates": n_updates, "batch": batch,
        "updates_per_s": round(n_updates / total, 2),
        "txns_per_s": round(n_updates * batch / total, 1),
        "paths": dict(paths), "n_remines": miner.n_remines - 1,
        "p50_ms": round(float(np.percentile(upd_ms, 50)), 3),
        "p99_ms": round(float(np.percentile(upd_ms, 99)), 3),
        "n_tracked": miner.n_tracked,
        "n_frequent": miner.n_frequent,
    }
    rows.append((f"stream_updates/mushroom/B={batch}",
                 round(total / n_updates * 1e6, 1),
                 f"updates_per_s={record['updates']['updates_per_s']} "
                 f"paths={dict(paths)}"))

    # -- rule refresh latency -------------------------------------------------
    record["rule_refresh"] = {
        "n_refreshes": int(refresh_ms.size),
        "p50_ms": round(float(np.percentile(refresh_ms, 50)), 3)
        if refresh_ms.size else 0.0,
        "p99_ms": round(float(np.percentile(refresh_ms, 99)), 3)
        if refresh_ms.size else 0.0,
    }
    rows.append(("stream_rule_refresh",
                 record["rule_refresh"]["p50_ms"] * 1e3,
                 f"refreshes={refresh_ms.size} "
                 f"p50={record['rule_refresh']['p50_ms']}ms "
                 f"p99={record['rule_refresh']['p99_ms']}ms"))

    # -- delta counting vs full recount of the same tracked candidates -------
    tracked = miner._tables.cat_padded
    contents = miner.window.contents()         # a representative slab: one
    added, evicted = contents[-batch:], contents[:batch]   # batch in/out
    dev_window = miner.window.device_masks()   # device-resident full window
    t_delta = _best_of(lambda: delta_count(tracked, added, evicted))
    t_full = _best_of(lambda: support_count(tracked, dev_window))
    speedup = t_full / max(t_delta, 1e-9)
    record["delta_vs_recount"] = {
        "n_tracked": int(tracked.shape[0]),
        "window": miner.window.size, "slab": int(added.shape[0]
                                                 + evicted.shape[0]),
        "delta_ms": round(t_delta * 1e3, 3),
        "recount_ms": round(t_full * 1e3, 3),
        "speedup": round(speedup, 2),
    }
    rows.append((f"stream_delta_vs_recount/C={tracked.shape[0]}",
                 round(t_delta * 1e6, 1),
                 f"delta={t_delta*1e3:.2f}ms recount={t_full*1e3:.2f}ms "
                 f"speedup={speedup:.1f}x"))

    write_json("BENCH_stream.json", record)
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
