"""Paper Fig. 5(b): speedup vs number of DataNodes.

Each device count runs in a subprocess with its own
``--xla_force_host_platform_device_count`` (the host-device simulation of a
bigger cluster).  NOTE (recorded in EXPERIMENTS.md): on this 1-core container
host devices time-share one CPU, so wall-clock speedup is expected to be flat —
the benchmark validates the *harness* (shards scale, answers agree) and
reports per-device work reduction; real scaling numbers need real chips.
"""

import json
import os
import subprocess
import sys

from .common import emit

_CHILD = r"""
import json, time, sys
import numpy as np
from repro.data import dataset_by_name
from repro.core import mine
from repro.core.mapreduce import MapReduceRuntime
txns, n_items = dataset_by_name("c20d10k", scale=0.1)
rt = MapReduceRuntime()
t0 = time.perf_counter()
res = mine(txns, n_items=n_items, min_sup=0.35, algorithm="%s", runtime=rt)
wall = time.perf_counter() - t0
import jax
sizes = {k: int(v[0].shape[0]) for k, v in res.levels.items()}
print(json.dumps({"wall": wall, "devices": len(jax.devices()),
                  "rows_counted": rt.stats.rows_counted,
                  "dispatches": rt.stats.dispatches, "levels": sizes}))
"""


def run(fast: bool = False):
    rows = []
    counts = [1, 4] if fast else [1, 2, 4, 8]
    for algo in ["vfpc", "optimized_vfpc"] if not fast else ["optimized_vfpc"]:
        base = None
        ref_levels = None
        for n in counts:
            env = dict(os.environ)
            env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
            env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
            r = subprocess.run([sys.executable, "-c", _CHILD % algo],
                               capture_output=True, text=True, env=env,
                               timeout=600)
            assert r.returncode == 0, r.stderr
            data = json.loads(r.stdout.strip().splitlines()[-1])
            if ref_levels is None:
                ref_levels = data["levels"]
                base = data["wall"]
            assert data["levels"] == ref_levels, "answers must agree across meshes"
            rows.append((f"fig5b_speedup/{algo}/devices={n}",
                         round(data["wall"] * 1e6 / data["dispatches"], 1),
                         f"wall={data['wall']:.3f}s speedup={base/data['wall']:.2f} "
                         f"dispatches={data['dispatches']}"))
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
