"""Paper Figs. 2–4: execution time of all seven algorithms for varying
minimum support on (stand-ins for) c20d10k, chess and mushroom."""

from .common import ALGOS, DATASETS, emit, load, timed_mine

MIN_SUPS = {
    "c20d10k": [0.25, 0.20, 0.15, 0.125],
    "chess": [0.75, 0.68, 0.60, 0.55],
    "mushroom": [0.45, 0.40, 0.35, 0.31],
}


def run(fast: bool = False):
    rows = []
    for ds in DATASETS:
        txns, n_items = load(ds)
        sups = MIN_SUPS[ds][-2:] if fast else MIN_SUPS[ds]
        algos = ["spc", "fpc", "vfpc", "optimized_vfpc"] if fast else ALGOS
        base_levels = None
        for sup in sups:
            for algo in algos:
                res, wall = timed_mine(txns, n_items, sup, algo)
                levels = {k: v[0].shape[0] for k, v in res.levels.items()}
                if (sup, ds) == (sups[0], ds) and base_levels is None:
                    base_levels = levels
                rows.append((f"fig_exec/{ds}/{algo}/sup={sup}",
                             round(wall * 1e6 / max(res.dispatches, 1), 1),
                             f"wall={wall:.3f}s phases={res.n_phases} "
                             f"dispatches={res.dispatches} max_k={max(levels)}"))
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
