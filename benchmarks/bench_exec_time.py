"""Paper Figs. 2–4: execution time of all seven algorithms for varying
minimum support on (stand-ins for) c20d10k, chess and mushroom.

Additionally A/B-measures the device-resident phase pipeline (DESIGN.md §4):
``before`` = the legacy synchronous/unfused loop with the pairwise join (the
pre-pipeline tree), ``after`` = fused + async counting with speculative
overlap, prefix-grouped join and autotuned blocks.  The per-config wall
times, speedups and overlap seconds are written to ``BENCH_exec_time.json``
so the perf trajectory is tracked across PRs.
"""

import jax

from .common import ALGOS, DATASETS, MapReduceRuntime, emit, load, timed_mine, write_json

MIN_SUPS = {
    "c20d10k": [0.25, 0.20, 0.15, 0.125],
    "chess": [0.75, 0.68, 0.60, 0.55],
    "mushroom": [0.45, 0.40, 0.35, 0.31],
}

# the paper's headline algorithms get the pipeline A/B treatment
AB_ALGOS = ["optimized_vfpc", "optimized_etdpc"]


def run(fast: bool = False):
    rows = []
    record = {"backend": jax.default_backend(), "pipeline_ab": {}, "grid": {}}
    for ds in DATASETS:
        txns, n_items = load(ds)
        sups = MIN_SUPS[ds][-2:] if fast else MIN_SUPS[ds]
        algos = ["spc", "fpc", "vfpc", "optimized_vfpc"] if fast else ALGOS
        for sup in sups:
            for algo in algos:
                res, wall = timed_mine(txns, n_items, sup, algo)
                levels = {k: v[0].shape[0] for k, v in res.levels.items()}
                record["grid"][f"{ds}/{algo}/sup={sup}"] = round(wall, 4)
                rows.append((f"fig_exec/{ds}/{algo}/sup={sup}",
                             round(wall * 1e6 / max(res.dispatches, 1), 1),
                             f"wall={wall:.3f}s phases={res.n_phases} "
                             f"dispatches={res.dispatches} max_k={max(levels)}"))

        # -- pipeline before/after on the paper's headline algorithms ---------
        if fast and ds != "mushroom":
            continue          # CI smoke: one dataset's A/B is enough
        sup = DATASETS[ds]["min_sup"]
        reps = 2 if fast else 3
        for algo in AB_ALGOS:
            res_b, wall_b = timed_mine(
                txns, n_items, sup, algo, warm=True, reps=reps,
                runtime=MapReduceRuntime(autotune=False), pipeline=False)
            res_a, wall_a = timed_mine(
                txns, n_items, sup, algo, warm=True, reps=reps,
                runtime=MapReduceRuntime(autotune=True), pipeline=True)
            assert res_b.itemsets() == res_a.itemsets(), (ds, algo)
            speedup = wall_b / wall_a if wall_a > 0 else float("inf")
            record["pipeline_ab"][f"{ds}/{algo}"] = {
                "before_s": round(wall_b, 4),
                "after_s": round(wall_a, 4),
                "speedup": round(speedup, 2),
                "overlap_s": round(res_a.overlap_seconds, 4),
            }
            rows.append((f"pipeline_ab/{ds}/{algo}/sup={sup}",
                         round(wall_a * 1e6, 1),
                         f"before={wall_b:.3f}s after={wall_a:.3f}s "
                         f"speedup={speedup:.2f}x "
                         f"overlap={res_a.overlap_seconds*1e3:.1f}ms"))
    ab = record["pipeline_ab"]
    if ab:
        sp = [v["speedup"] for v in ab.values()]
        geo = 1.0
        for s in sp:
            geo *= s
        record["speedup_geomean"] = round(geo ** (1 / len(sp)), 2)
        record["overlap_total_s"] = round(sum(v["overlap_s"] for v in ab.values()), 4)
    write_json("BENCH_exec_time.json", record)
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
