"""Support-count kernel microbenchmark + roofline terms for the counting phase.

On CPU the jnp (XLA) horizontal path and the vertical gather-scan are the
production paths and are timed; the Pallas kernels are validated in interpret
mode (their TPU roofline terms are derived analytically: both are pure VPU
bitwise op streams).  Autotuned block choices and per-impl throughput are
written to ``BENCH_kernels.json`` so the perf trajectory is tracked across
PRs.
"""

import time

import jax
import numpy as np

import jax.numpy as jnp

from repro.core.bitset import pack_itemsets, vertical_pack
from repro.core.mapreduce import MapReduceRuntime
from repro.data import dataset_by_name
from repro.kernels import (tuned_blocks, vertical_count_jnp,
                           vertical_count_pallas)
from repro.kernels.ops import _support_count_jnp

from .common import emit, write_json


def _time(fn, reps=3):
    jax.block_until_ready(fn())           # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(fast: bool = False):
    rows = []
    record = {"backend": jax.default_backend(), "autotuned": {}, "kernels": {}}
    txns, n_items = dataset_by_name("mushroom", scale=0.25 if fast else 1.0)
    db = pack_itemsets([list(t) for t in txns], n_items)
    vdb = vertical_pack(db, n_items)
    rng = np.random.default_rng(0)
    W = db.shape[1]
    rt = MapReduceRuntime()  # only for _padded_indices
    rt._n_items = n_items

    for C in [256, 2048] if fast else [256, 2048, 16384]:
        idx = rng.integers(0, len(db), C)
        cands = db[idx]
        cand_idx = rt._padded_indices(cands)
        kmax = cand_idx.shape[1]

        # horizontal jnp (XLA) path, timed with the autotuned txn block
        cfg = tuned_blocks("jnp", C=C, T=len(db), W=W)
        cj, dj = jnp.asarray(cands), jnp.asarray(db)
        blk = min(cfg["txn_block"], len(db))
        wall = _time(lambda: _support_count_jnp(cj, dj, block=blk))
        pairs = C * len(db)
        ops = pairs * (W * 3 + 1)            # and, cmp, and-reduce, add
        bytes_hbm = (C * W + len(db) * W) * 4  # each tile read once (blocked)
        name = f"kernel_support_count/C={C}/T={len(db)}"
        record["kernels"][name] = {"impl": "jnp", "us": round(wall * 1e6, 1),
                                   "gops_cpu": round(ops / wall / 1e9, 2)}
        record["autotuned"][f"jnp/C={C}"] = cfg
        rows.append((name, round(wall * 1e6, 1),
                     f"pairs={pairs} gops={ops/wall/1e9:.2f}(cpu) "
                     f"tpu_compute_s={ops/197e12:.2e} tpu_mem_s={bytes_hbm/819e9:.2e}"))

        # vertical gather-scan (CPU production path), autotuned block
        vcfg = tuned_blocks("vertical", C=C, T=vdb.shape[1], W=W, kmax=kmax)
        wall_v = _time(lambda: vertical_count_jnp(vdb, cand_idx, **vcfg))
        words = C * kmax * vdb.shape[1]
        namev = f"kernel_vertical_count/C={C}/Tw={vdb.shape[1]}/k={kmax}"
        record["kernels"][namev] = {
            "impl": "vertical", "us": round(wall_v * 1e6, 1),
            "block": vcfg, "gwords_cpu": round(words / wall_v / 1e9, 2)}
        record["autotuned"][f"vertical/C={C}"] = vcfg
        rows.append((namev, round(wall_v * 1e6, 1),
                     f"words={words} block={vcfg} "
                     f"speedup_vs_horizontal={wall/wall_v:.1f}x"))

    # Pallas vertical kernel: interpret-mode validation on a tiny slice
    Cs, ks = 64, 3
    idx_small = rt._padded_indices(db[rng.integers(0, len(db), Cs)])[:, :ks]
    ref = np.asarray(vertical_count_jnp(vdb, idx_small))
    got = np.asarray(vertical_count_pallas(vdb, idx_small, interpret=True))
    ok = bool((ref == got).all())
    record["kernels"]["vertical_pallas_interpret_valid"] = ok
    rows.append(("kernel_vertical_pallas/interpret_valid", int(ok),
                 f"C={Cs} kmax={ks} matches_jnp={ok}"))

    write_json("BENCH_kernels.json", record)
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
