"""Support-count kernel microbenchmark + roofline terms for the counting phase.

Each counting formulation (DESIGN.md §10) is timed on its production path:
the popcount-AND subset test ("jnp"), its bit-plane int8 ``dot_general`` twin
("matmul"), and the vertical gather-scan with its membership-matmul twin.
Pallas variants are validated in interpret mode (their TPU roofline terms are
analytic).  Every timed record carries its achieved-vs-peak roofline fraction
(``count_kernel_roofline``) and each shape gets a ``count_winner`` row pairing
the measured argmin with the autotuner plan pick — the regression guard for
the C=256 vertical own-goal.  Autotuned blocks and per-impl throughput land in
``BENCH_kernels.json`` so the perf trajectory is tracked across PRs.
"""

import time

import jax
import numpy as np

import jax.numpy as jnp

from repro.core.bitset import pack_itemsets, vertical_pack
from repro.core.mapreduce import MapReduceRuntime
from repro.data import dataset_by_name
from repro.kernels import (support_count_matmul, tuned_blocks, tuned_plan,
                           vertical_count_jnp, vertical_count_matmul,
                           vertical_count_pallas)
from repro.kernels.ops import _support_count_jnp
from repro.kernels.support_count import support_count_matmul_pallas
from repro.roofline import count_kernel_roofline

from .common import emit, write_json


def _time(fn, reps=3):
    jax.block_until_ready(fn())           # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _roof(family, *, C, T, W=1, kmax=1, seconds, backend):
    r = count_kernel_roofline(family, C=C, T=T, W=W, kmax=kmax,
                              seconds=seconds, backend=backend)
    return {"bound": r["bound"], "peak_frac": round(r["peak_frac"], 4)}


def run(fast: bool = False):
    rows = []
    backend = jax.default_backend()
    record = {"backend": backend, "autotuned": {}, "kernels": {},
              "count_winner": {}}
    txns, n_items = dataset_by_name("mushroom", scale=0.25 if fast else 1.0)
    db = pack_itemsets([list(t) for t in txns], n_items)
    vdb = vertical_pack(db, n_items)
    rng = np.random.default_rng(0)
    W = db.shape[1]
    rt = MapReduceRuntime()  # only for _padded_indices
    rt._n_items = n_items

    for C in [256, 2048] if fast else [256, 2048, 16384]:
        idx = rng.integers(0, len(db), C)
        cands = db[idx]
        cand_idx = rt._padded_indices(cands)
        kmax = cand_idx.shape[1]
        T = len(db)
        timed = {}

        # horizontal jnp (XLA) popcount path, autotuned txn block
        cfg = tuned_blocks("jnp", C=C, T=T, W=W)
        cj, dj = jnp.asarray(cands), jnp.asarray(db)
        blk = min(cfg["txn_block"], T)
        wall = _time(lambda: _support_count_jnp(cj, dj, block=blk))
        timed["jnp"] = wall
        pairs = C * T
        ops = pairs * (W * 3 + 1)            # and, cmp, and-reduce, add
        name = f"kernel_support_count/C={C}/T={T}"
        record["kernels"][name] = {
            "impl": "jnp", "us": round(wall * 1e6, 1),
            "gops_cpu": round(ops / wall / 1e9, 2),
            "roofline": _roof("jnp", C=C, T=T, W=W, seconds=wall,
                              backend=backend)}
        record["autotuned"][f"jnp/C={C}"] = cfg
        rows.append((name, round(wall * 1e6, 1),
                     f"pairs={pairs} gops={ops/wall/1e9:.2f}(cpu) "
                     f"frac={record['kernels'][name]['roofline']['peak_frac']}"))

        # horizontal bit-plane matmul twin (int8 dot_general)
        mcfg = tuned_blocks("matmul", C=C, T=T, W=W)
        mblk = min(mcfg["txn_block"], T)
        wall_m = _time(lambda: support_count_matmul(cj, dj, block=mblk))
        timed["matmul"] = wall_m
        namem = f"kernel_support_count_matmul/C={C}/T={T}"
        macs = C * T * W * 32
        record["kernels"][namem] = {
            "impl": "matmul", "us": round(wall_m * 1e6, 1),
            "gmacs_cpu": round(macs / wall_m / 1e9, 2),
            "roofline": _roof("matmul", C=C, T=T, W=W, seconds=wall_m,
                              backend=backend)}
        record["autotuned"][f"matmul/C={C}"] = mcfg
        rows.append((namem, round(wall_m * 1e6, 1),
                     f"gmacs={macs/wall_m/1e9:.2f}(cpu) "
                     f"vs_jnp={wall/wall_m:.2f}x "
                     f"frac={record['kernels'][namem]['roofline']['peak_frac']}"))

        # vertical gather-scan (popcount) path, autotuned block
        Tw = vdb.shape[1]
        vcfg = tuned_blocks("vertical", C=C, T=Tw, W=W, kmax=kmax)
        wall_v = _time(lambda: vertical_count_jnp(vdb, cand_idx, **vcfg))
        timed["vertical"] = wall_v
        words = C * kmax * Tw
        namev = f"kernel_vertical_count/C={C}/Tw={Tw}/k={kmax}"
        record["kernels"][namev] = {
            "impl": "vertical", "us": round(wall_v * 1e6, 1),
            "block": vcfg, "gwords_cpu": round(words / wall_v / 1e9, 2),
            "roofline": _roof("vertical", C=C, T=Tw * 32, kmax=kmax,
                              seconds=wall_v, backend=backend)}
        record["autotuned"][f"vertical/C={C}"] = vcfg
        rows.append((namev, round(wall_v * 1e6, 1),
                     f"words={words} block={vcfg} "
                     f"speedup_vs_horizontal={wall/wall_v:.1f}x"))

        # vertical membership-matmul twin
        vmcfg = tuned_blocks("vertical_matmul", C=C, T=Tw, W=W, kmax=kmax)
        vj, ij = jnp.asarray(vdb), jnp.asarray(cand_idx)
        wall_vm = _time(lambda: vertical_count_matmul(vj, ij, **vmcfg))
        timed["vertical_matmul"] = wall_vm
        namevm = f"kernel_vertical_count_matmul/C={C}/Tw={Tw}/k={kmax}"
        record["kernels"][namevm] = {
            "impl": "vertical_matmul", "us": round(wall_vm * 1e6, 1),
            "block": vmcfg,
            "roofline": _roof("vertical", C=C, T=Tw * 32, kmax=kmax,
                              seconds=wall_vm, backend=backend)}
        record["autotuned"][f"vertical_matmul/C={C}"] = vmcfg
        rows.append((namevm, round(wall_vm * 1e6, 1),
                     f"vs_vertical={wall_v/wall_vm:.2f}x"))

        # per-shape winner: measured argmin + the autotuner's plan pick.
        # Plan must never be slower than the previous single-family winner
        # (the C=256 vertical own-goal this PR fixes).
        best = min(timed, key=timed.get)
        plan = tuned_plan("count", C=C, T=T, W=W, kmax=kmax)
        record["count_winner"][f"C={C}"] = {
            "measured_best": best,
            "measured_us": {k: round(v * 1e6, 1) for k, v in timed.items()},
            "plan": None if plan is None else
            {"impl": plan["impl"], "family": plan["family"]}}
        rows.append((f"count_winner/C={C}",
                     round(timed[best] * 1e6, 1),
                     f"measured_best={best} "
                     f"plan={'off' if plan is None else plan['impl']}"))

    # Pallas kernels: interpret-mode validation on a tiny slice
    Cs, ks = 64, 3
    idx_small = rt._padded_indices(db[rng.integers(0, len(db), Cs)])[:, :ks]
    ref = np.asarray(vertical_count_jnp(vdb, idx_small))
    got = np.asarray(vertical_count_pallas(vdb, idx_small, interpret=True))
    ok = bool((ref == got).all())
    record["kernels"]["vertical_pallas_interpret_valid"] = ok
    rows.append(("kernel_vertical_pallas/interpret_valid", int(ok),
                 f"C={Cs} kmax={ks} matches_jnp={ok}"))

    csmall, tsmall = jnp.asarray(db[:64]), jnp.asarray(db[:128])
    refm = np.asarray(_support_count_jnp(csmall, tsmall, block=128))
    gotm = np.asarray(support_count_matmul_pallas(csmall, tsmall, bc=32,
                                                  bt=64, interpret=True))
    okm = bool((refm == gotm).all())
    record["kernels"]["matmul_pallas_interpret_valid"] = okm
    rows.append(("kernel_matmul_pallas/interpret_valid", int(okm),
                 f"C=64 T=128 matches_jnp={okm}"))

    write_json("BENCH_kernels.json", record)
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
