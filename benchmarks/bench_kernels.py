"""Support-count kernel microbenchmark + roofline terms for the counting phase.

On CPU the jnp (XLA) path is the production path and is timed; the Pallas
kernel is validated in interpret mode (its TPU roofline terms are derived
analytically: the kernel is a pure VPU bitwise op stream).
"""

import time

import jax
import numpy as np

from repro.core.bitset import pack_itemsets
from repro.data import dataset_by_name
from repro.kernels import support_count

from .common import emit


def run(fast: bool = False):
    rows = []
    txns, n_items = dataset_by_name("mushroom", scale=0.25 if fast else 1.0)
    db = pack_itemsets([list(t) for t in txns], n_items)
    rng = np.random.default_rng(0)
    for C in [256, 2048] if fast else [256, 2048, 16384]:
        idx = rng.integers(0, len(db), C)
        cands = db[idx]
        out = support_count(cands, db, impl="jnp")
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            out = support_count(cands, db, impl="jnp")
        jax.block_until_ready(out)
        wall = (time.perf_counter() - t0) / reps
        pairs = C * len(db)
        # analytic TPU roofline for the Pallas kernel (bitwise AND+cmp+reduce):
        W = db.shape[1]
        ops = pairs * (W * 3 + 1)            # and, cmp, and-reduce, add
        bytes_hbm = (C * W + len(db) * W) * 4  # each tile read once (blocked)
        rows.append((f"kernel_support_count/C={C}/T={len(db)}",
                     round(wall * 1e6, 1),
                     f"pairs={pairs} gops={ops/wall/1e9:.2f}(cpu) "
                     f"tpu_compute_s={ops/197e12:.2e} tpu_mem_s={bytes_hbm/819e9:.2e}"))
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
