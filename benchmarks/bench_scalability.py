"""Paper Fig. 5(a): execution time on increasing dataset size (fixed min_sup,
fixed mapper count — here fixed device count)."""

from .common import emit, load, timed_mine


def run(fast: bool = False):
    rows = []
    scales = [0.05, 0.1] if fast else [0.05, 0.1, 0.2, 0.4]
    for algo in (["optimized_vfpc"] if fast
                 else ["vfpc", "optimized_vfpc", "etdpc", "optimized_etdpc"]):
        for s in scales:
            txns, n_items = load("c20d10k", scale=s)
            res, wall = timed_mine(txns, n_items, 0.25, algo)
            rows.append((f"fig5a_scale/{algo}/n={len(txns)}",
                         round(wall * 1e6 / len(txns), 2),
                         f"wall={wall:.3f}s phases={res.n_phases}"))
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
