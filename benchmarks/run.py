# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark runner — one module per paper table/figure (DESIGN.md §6).

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]
"""

import argparse
import sys
import time

from . import (bench_candidates, bench_costmodel, bench_decode_fusion,
               bench_exec_time, bench_kernels, bench_lk_counts,
               bench_phase_breakdown, bench_rules, bench_scalability,
               bench_scaling, bench_speedup, bench_stream)

SUITES = {
    "exec_time": bench_exec_time,          # Figs. 2-4
    "phase_breakdown": bench_phase_breakdown,  # Tables 3-5, 10-12
    "lk_counts": bench_lk_counts,          # Table 6
    "candidates": bench_candidates,        # Tables 7-9
    "scalability": bench_scalability,      # Fig. 5(a)
    "speedup": bench_speedup,              # Fig. 5(b)
    "decode_fusion": bench_decode_fusion,  # beyond-paper serving fusion
    "kernels": bench_kernels,              # Pallas/counting microbench
    "rules": bench_rules,                  # rule generation + serving (§7)
    "stream": bench_stream,                # streaming incremental mining (§8)
    "costmodel": bench_costmodel,          # calibrated cost model (§9)
    "scaling": bench_scaling,              # device-count scaling curves (§11)
}


# the CI pass: pipeline A/B + kernels + rule subsystem + streaming + costmodel
SMOKE_SUITES = ("exec_time", "kernels", "rules", "stream", "costmodel")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced datasets/algorithms (CI-sized)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: --fast sizes, exec_time + kernels only")
    ap.add_argument("--only", default=None, choices=sorted(SUITES))
    args = ap.parse_args()

    if args.smoke:
        args.fast = True
    suites = {args.only: SUITES[args.only]} if args.only else (
        {k: SUITES[k] for k in SMOKE_SUITES} if args.smoke else SUITES)
    t0 = time.time()
    for name, mod in suites.items():
        print(f"== {name} ==", flush=True)
        try:
            mod.run(fast=args.fast)
        except Exception as e:  # keep the suite going; a failed bench is loud
            print(f"name,us_per_call,derived\n{name}/FAILED,0,{type(e).__name__}: {e}\n",
                  flush=True)
    print(f"# total benchmark wall time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
