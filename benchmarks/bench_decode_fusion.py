"""Beyond-paper: the paper's pass-fusion policies applied to LM serving.

Measures decode dispatch amortization — tokens/s and dispatch counts per
policy for a smoke-config model.  The dispatch overhead on CPU plays the role
of Hadoop job-scheduling overhead; the orderings (SPC slowest, fused variants
fewer dispatches) are the serving-layer analogue of the paper's Figs. 2–4."""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import ServeEngine

from .common import emit


def run(fast: bool = False):
    cfg = get_config("smollm-135m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, (8, 8)).astype(np.int32)
    max_new = 32 if fast else 64
    algos = (["spc", "fpc", "optimized_vfpc"] if fast
             else ["spc", "fpc", "dpc", "vfpc", "etdpc",
                   "optimized_vfpc", "optimized_etdpc"])
    rows = []
    outs = {}
    variants = [(a, 1) for a in algos]
    variants.append(("optimized_vfpc", 2))   # pipelined dispatch (depth 2)
    for algo, depth in variants:
        eng = ServeEngine(model, params, cache_len=8 + max_new + 8,
                          algorithm=algo, pipeline_depth=depth)
        # full-length warm pass: budget policies (dpc/etdpc) choose widths at
        # runtime, so a short warmup would leave npass variants uncompiled and
        # pollute the measurement with mid-run jit compiles
        eng.generate(prompts, max_new_tokens=max_new, eos_id=-1)
        t0 = time.perf_counter()
        toks, recs = eng.generate(prompts, max_new_tokens=max_new, eos_id=-1)
        wall = time.perf_counter() - t0
        name = algo if depth == 1 else f"{algo}+pipelined{depth}"
        outs[name] = toks
        n_tok = int((toks != 0).sum())
        rows.append((f"decode_fusion/{name}",
                     round(wall * 1e6 / max(len(recs), 1), 1),
                     f"dispatches={len(recs)} tok/s={n_tok/wall:.1f} "
                     f"wall={wall:.3f}s"))
    base = outs[algos[0]]
    for name, t in outs.items():
        assert (t == base).all(), f"{name} output diverged"
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
