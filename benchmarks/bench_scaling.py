"""Strong/weak scaling curves vs. device count (DESIGN.md §11).

The paper's Fig. 5 varies the Hadoop cluster size; here the cluster is a
device mesh, simulated on one host via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.  That flag is fixed
at process start, so each device count runs in a fresh worker subprocess
(``python -m benchmarks.bench_scaling --worker <cfg>``) that mines once and
reports a JSON record; the parent sweeps the counts and writes
``BENCH_scaling.json``.

Arms:

* **strong** — fixed dataset, growing mesh: wall time per device count and
  speedup vs. 1 device.  On a single physical CPU the simulated devices add
  no parallel compute, so the honest win is cache locality: per-shard
  vertical bitmaps fit cache at transaction counts where the monolithic
  layout does not (large-scale c20d10k, vertical impl).
* **weak** — dataset grows with the mesh (scale ∝ devices): per-transaction
  time should stay flat when sharding is efficient.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit, write_json

_MARK = "@@SCALING@@ "

# full-mode arms: the large-T regime where per-shard cache residency wins
STRONG = {"dataset": "c20d10k", "scale": 64.0, "min_sup": 0.25,
          "impl": "vertical", "algorithm": "optimized_etdpc"}
WEAK = {"dataset": "c20d10k", "scale_per_device": 4.0, "min_sup": 0.25,
        "impl": "vertical", "algorithm": "optimized_etdpc"}
DEVICES = [1, 2, 4, 8]

SMOKE_STRONG = {"dataset": "c20d10k", "scale": 0.5, "min_sup": 0.25,
                "impl": "vertical", "algorithm": "optimized_etdpc"}
SMOKE_WEAK = {"dataset": "c20d10k", "scale_per_device": 0.1, "min_sup": 0.25,
              "impl": "vertical", "algorithm": "optimized_etdpc"}
SMOKE_DEVICES = [1, 8]


def _worker(cfg: dict) -> None:
    """Mine once at the current (already-forced) device count; print JSON."""
    from repro.core.mapreduce import MapReduceRuntime
    from repro.launch.mesh import make_mining_mesh

    from .common import load, timed_mine

    txns, n_items = load(cfg["dataset"], scale=cfg["scale"])
    runtime = MapReduceRuntime(mesh=make_mining_mesh(n_cand=cfg["n_cand"]),
                               impl=cfg["impl"],
                               cand_axis="cand" if cfg["n_cand"] > 1 else None)
    res, wall = timed_mine(txns, n_items, cfg["min_sup"], cfg["algorithm"],
                           warm=True, runtime=runtime, elastic=False)
    print(_MARK + json.dumps({
        "devices": runtime.mesh.size, "mesh": list(runtime.mesh_split),
        "n_txns": len(txns), "wall": wall, "phases": res.n_phases,
        "dispatches": res.dispatches,
        "levels": {str(k): int(v[0].shape[0]) for k, v in res.levels.items()},
    }))


def _spawn(cfg: dict, n_devices: int) -> dict | None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_devices}"
                        ).strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_scaling",
         "--worker", json.dumps(cfg)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            return json.loads(line[len(_MARK):])
    print(f"# worker failed (devices={n_devices}): "
          f"{proc.stderr.strip().splitlines()[-1] if proc.stderr else '?'}")
    return None


def run(fast: bool = False):
    strong = dict(SMOKE_STRONG if fast else STRONG, n_cand=1)
    weak = dict(SMOKE_WEAK if fast else WEAK, n_cand=1)
    devices = SMOKE_DEVICES if fast else DEVICES

    rows = []
    strong_arms = []
    for n in devices:
        rec = _spawn(strong, n)
        if rec is None:
            continue
        strong_arms.append(rec)
        rows.append((f"scaling_strong/{strong['dataset']}/devices={n}",
                     round(rec["wall"] * 1e6 / rec["n_txns"], 3),
                     f"wall={rec['wall']:.3f}s mesh={rec['mesh']}"))

    weak_arms = []
    for n in devices:
        cfg = dict(weak, scale=weak["scale_per_device"] * n)
        rec = _spawn(cfg, n)
        if rec is None:
            continue
        weak_arms.append(rec)
        rows.append((f"scaling_weak/{weak['dataset']}/devices={n}",
                     round(rec["wall"] * 1e6 / rec["n_txns"], 3),
                     f"wall={rec['wall']:.3f}s n={rec['n_txns']}"))

    payload = {"mode": "smoke" if fast else "full",
               "strong": dict(strong, arms=strong_arms),
               "weak": dict(weak, arms=weak_arms)}
    by_dev = {a["devices"]: a["wall"] for a in strong_arms}
    if 1 in by_dev and max(by_dev) > 1:
        top = max(by_dev)
        payload["strong"]["speedup"] = {
            str(d): round(by_dev[1] / w, 4) for d, w in sorted(by_dev.items())}
        rows.append((f"scaling_strong/speedup_{top}x", 0,
                     f"{by_dev[1] / by_dev[top]:.3f}x vs 1 device"))
    if weak_arms:
        per_txn = {a["devices"]: a["wall"] / a["n_txns"] for a in weak_arms}
        base = per_txn[min(per_txn)]
        payload["weak"]["efficiency"] = {
            str(d): round(base / t, 4) for d, t in sorted(per_txn.items())}
    write_json("BENCH_scaling.json", payload)
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--worker", default=None, help="internal: JSON config")
    args = ap.parse_args()
    if args.worker:
        _worker(json.loads(args.worker))
    else:
        run(fast=args.smoke)
