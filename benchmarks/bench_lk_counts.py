"""Paper Table 6: number of frequent k-itemsets per level per dataset."""

from .common import DATASETS, emit, load, timed_mine


def run(fast: bool = False):
    rows = []
    for ds in (["mushroom"] if fast else list(DATASETS)):
        txns, n_items = load(ds)
        sup = DATASETS[ds]["min_sup"]
        res, wall = timed_mine(txns, n_items, sup, "spc")
        lk = [res.levels[k][0].shape[0] for k in sorted(res.levels)]
        rows.append((f"tbl6_lk/{ds}/sup={sup}",
                     round(wall * 1e6 / max(sum(lk), 1), 2),
                     "L=" + "/".join(map(str, lk))))
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
