"""Paper Tables 3–5 (SPC/FPC/VFPC/DPC/ETDPC phase-time breakdown) and
Tables 10–12 (optimized vs simple multi-pass phase elapsed time)."""

from .common import DATASETS, emit, load, timed_mine

TBL35 = ["spc", "fpc", "vfpc", "dpc", "etdpc"]
TBL1012 = ["vfpc", "optimized_vfpc", "etdpc", "optimized_etdpc"]


def run(fast: bool = False):
    rows = []
    datasets = ["mushroom"] if fast else list(DATASETS)
    for ds in datasets:
        txns, n_items = load(ds)
        sup = DATASETS[ds]["min_sup"]
        for algo in (TBL35 + TBL1012 if not fast else ["vfpc", "optimized_vfpc"]):
            res, wall = timed_mine(txns, n_items, sup, algo)
            per_phase = ";".join(
                f"k{p.k_start}-{p.k_start + p.npass - 1}:{p.elapsed_seconds*1e3:.0f}ms"
                f"(gen {p.gen_seconds*1e3:.0f} cnt {p.count_seconds*1e3:.0f})"
                for p in res.phases)
            total = sum(p.elapsed_seconds for p in res.phases)
            rows.append((f"tbl_phase/{ds}/{algo}",
                         round(total * 1e6 / max(res.n_phases, 1), 1),
                         f"total={total:.3f}s actual={wall:.3f}s "
                         f"phases={res.n_phases} [{per_phase}]"))
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
