"""Rule subsystem benchmark (DESIGN.md §6/§7/§12): vectorized rule generation
throughput and RuleServeEngine query serving, policy-fused vs per-batch.

Writes ``BENCH_rules.json``: rules/s for generation, queries/s and per-query
p50/p99 dispatch latency for the ``per_batch`` (SPC policy, one queued batch
per dispatch) and ``policy_fused`` (Optimized-VFPC micro-batching) arms, an
interpret-mode bit-exactness check of the Pallas containment kernel, and the
§12 ``open_loop`` arm — four tenants served through one packed arena under an
open-loop arrival clock with SLO admission, swept across offered rates to the
honest headline: **qps-at-p99-SLO** (the highest offered rate whose answered
p99 meets the SLO with ≤1% shed), plus the shed rate the admission controller
holds at overload — tracked across PRs by CI.
"""

import time

import jax
import numpy as np

from repro.core import generate_ruleset, mine
from repro.core.rules import generate_rules
from repro.costmodel import CostController
from repro.costmodel.model import CostModel
from repro.kernels.rule_match import rule_scores_jnp, rule_scores_pallas
from repro.launch.serve_rules import make_queries
from repro.serving import OpenLoopServer, RuleServeEngine, RuleStore
from repro.serving.common import latency_percentiles

from .common import emit, write_json

MIN_CONF = 0.6
# four tenants = four rule catalogs cut from one mined result at different
# confidence bars (different sizes, same item universe — tag bits isolate)
TENANT_CONFS = (0.6, 0.65, 0.7, 0.8)
SLO_MS = 25.0
MAX_SHED = 0.01               # "sustained" = p99 in SLO with ≤1% shed


def _serve_arm(rules, batches, algorithm, n_queries, warm_to):
    eng = RuleServeEngine(rules, top_k=5, algorithm=algorithm)
    eng.warmup(warm_to)
    t0 = time.perf_counter()
    _, records = eng.serve(batches)
    total = time.perf_counter() - t0
    lat = latency_percentiles(records)
    return {
        "qps": round(n_queries / total, 1),
        "p50_ms": round(lat["p50_ms"], 3),
        "p99_ms": round(lat["p99_ms"], 3),
        "dispatches": len(records),
        "fused_dispatches": sum(1 for r in records if r.n_batches > 1),
    }


def run(fast: bool = False):
    rows = []
    record = {"backend": jax.default_backend()}
    from repro.data import dataset_by_name
    txns, n_items = dataset_by_name("mushroom", scale=0.08 if fast else 0.25)
    res = mine(txns, n_items=n_items, min_sup=0.31)

    # -- rule generation: vectorized enumeration + device metric pass ---------
    generate_ruleset(res, min_confidence=MIN_CONF)          # warm (jit compile)
    best = float("inf")
    for _ in range(2 if fast else 3):
        t0 = time.perf_counter()
        rules = generate_ruleset(res, min_confidence=MIN_CONF)
        best = min(best, time.perf_counter() - t0)
    rules_per_s = len(rules) / max(best, 1e-9)
    record["generation"] = {
        "n_rules": len(rules), "gen_s": round(best, 4),
        "rules_per_s": round(rules_per_s, 1),
    }
    rows.append((f"rules_gen/mushroom/conf={MIN_CONF}",
                 round(best * 1e6, 1),
                 f"n_rules={len(rules)} rules_per_s={rules_per_s:,.0f}"))

    # decoded-view cost for context (per-rule host loop, not the hot path)
    t0 = time.perf_counter()
    generate_rules(res, min_confidence=MIN_CONF)
    decode_s = time.perf_counter() - t0
    record["generation"]["decode_s"] = round(decode_s, 4)

    if len(rules) == 0:            # dataset/config drift: record, don't crash
        rows.append(("rules/EMPTY", 0, f"no rules above conf={MIN_CONF}"))
        write_json("BENCH_rules.json", record)
        emit(rows, ["name", "us_per_call", "derived"])
        return rows

    # -- serving: policy-fused vs per-batch dispatch --------------------------
    n_queries = 256 if fast else 2048
    batch = 32
    queries = make_queries(txns, n_queries, seed=1)
    batches = [queries[i:i + batch] for i in range(0, len(queries), batch)]
    warm_to = batch * 16
    record["serving"] = {}
    for arm, algo in (("per_batch", "spc"), ("policy_fused", "optimized_vfpc")):
        stats = _serve_arm(rules, batches, algo, n_queries, warm_to)
        record["serving"][arm] = stats
        rows.append((f"rules_serve/{arm}/Q={n_queries}",
                     round(1e6 / stats["qps"], 1),
                     f"qps={stats['qps']} p50={stats['p50_ms']}ms "
                     f"p99={stats['p99_ms']}ms dispatches={stats['dispatches']} "
                     f"fused={stats['fused_dispatches']}"))
    fused = record["serving"]["policy_fused"]["qps"]
    per_batch = record["serving"]["per_batch"]["qps"]
    record["serving"]["fused_speedup"] = round(fused / per_batch, 2)

    # -- open loop: 4 tenants, one arena, qps-at-p99-SLO (DESIGN.md §12) ------
    tenant_rules = {f"t{i}": generate_ruleset(res, min_confidence=c)
                    for i, c in enumerate(TENANT_CONFS)}
    store = RuleStore(tenants=tenant_rules)
    controller = CostController(model=CostModel(persist=False))
    eng = RuleServeEngine(store, top_k=5, algorithm="optimized_vfpc",
                          controller=controller)
    eng.warmup(32 * 4)
    names = list(tenant_rules)
    n_ol = 256 if fast else 1024
    ol_queries = [(names[i % len(names)], q)
                  for i, q in enumerate(make_queries(txns, n_ol, seed=2))]
    rng = np.random.default_rng(3)

    rates = (500, 1000, 2000) if fast else (500, 1000, 2000, 4000, 8000)
    sweep, qps_at_slo, shed_at_max = [], 0.0, 0.0
    for rate in rates:
        srv = OpenLoopServer(eng, latency_slo_ms=SLO_MS, batch=32,
                             max_wait_ms=5.0, cache_size=0,
                             controller=controller)
        gaps = rng.uniform(0.7, 1.3, n_ol) / rate
        t = 0.0
        for (tenant, q), gap in zip(ol_queries, gaps):
            t += gap
            srv.submit(q, t, tenant=tenant)
        srv.flush()
        s = srv.summary()
        answered = s["served"] + s["cached"]
        sustained = answered / max(srv.busy_until, t, 1e-9)
        point = {"offered_qps": rate, "sustained_qps": round(sustained, 1),
                 "p99_ms": round(s["p99_ms"], 3),
                 "shed_rate": round(s["shed_rate"], 4)}
        sweep.append(point)
        shed_at_max = s["shed_rate"]
        if s["p99_ms"] <= SLO_MS and s["shed_rate"] <= MAX_SHED:
            qps_at_slo = max(qps_at_slo, sustained)
    record["serving"]["open_loop"] = {
        "n_tenants": len(tenant_rules),
        "tenant_rules": {t: len(r) for t, r in tenant_rules.items()},
        "latency_slo_ms": SLO_MS,
        "rates": sweep,
        "qps_at_slo": round(qps_at_slo, 1),
        "shed_rate_at_max_offered": round(shed_at_max, 4),
    }
    rows.append((f"rules_serve/open_loop/tenants={len(tenant_rules)}",
                 round(1e6 / max(qps_at_slo, 1e-9), 1),
                 f"qps_at_p99_slo={qps_at_slo:,.0f} (slo={SLO_MS}ms) "
                 f"shed_at_{rates[-1]}qps={shed_at_max:.1%}"))

    # -- Pallas containment kernel: interpret-mode bit-exactness --------------
    rng = np.random.default_rng(0)
    sl = slice(0, min(len(rules), 64))
    baskets = rules.ante_masks[rng.integers(0, len(rules), 32)]
    ref = np.asarray(rule_scores_jnp(
        rules.ante_masks[sl], rules.cons_masks[sl], rules.score[sl], baskets))
    got = np.asarray(rule_scores_pallas(
        rules.ante_masks[sl], rules.cons_masks[sl], rules.score[sl], baskets,
        bq=8, br=128, interpret=True))
    ok = bool((ref == got).all())
    record["rules_pallas_interpret_valid"] = ok
    rows.append(("rules_pallas/interpret_valid", int(ok),
                 f"R={sl.stop} Q=32 matches_jnp={ok}"))

    write_json("BENCH_rules.json", record)
    emit(rows, ["name", "us_per_call", "derived"])
    return rows


if __name__ == "__main__":
    run()
