"""End-to-end training driver: train smollm-135m (the assigned ~100M-class
architecture) for a few hundred steps with paper-policy fused phases,
checkpointing every few phases.

The full 135M config at seq 512 is CPU-heavy; pass --full to use it (default
uses the reduced config so the example completes in minutes).

  PYTHONPATH=src python examples/train_lm.py [--full] [--steps 300]
"""

import argparse

import jax

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import TrainLoop, init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="real 135M config (slow on CPU)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    steps = args.steps or (300 if args.full else 120)
    cfg = get_config("smollm-135m", smoke=not args.full)
    model = build_model(cfg)
    print(f"model: {cfg.name}  params≈{cfg.param_count()/1e6:.1f}M")

    pipe = TokenPipeline(vocab_size=cfg.vocab_size,
                         seq_len=512 if args.full else 64,
                         global_batch=8 if args.full else 16)
    opt = AdamWConfig(lr=6e-4, warmup_steps=steps // 10, total_steps=steps)

    loop = TrainLoop(model, pipe, opt, algorithm="vfpc",
                     checkpoint_dir=args.ckpt)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    state, records = loop.run(state, total_steps=steps)

    first = records[0].mean_loss
    last = records[-1].mean_loss
    n_disp = len(records)
    print(f"\n{steps} steps in {n_disp} fused phases "
          f"({steps/n_disp:.1f} steps/dispatch)")
    print(f"loss: {first:.3f} → {last:.3f}")
    assert last < first, "loss must decrease"
    for r in records[:: max(1, n_disp // 10)]:
        print(f"  phase {r.phase_idx:3d} npass={r.npass} "
              f"loss={r.mean_loss:.3f} {r.elapsed:.2f}s")


if __name__ == "__main__":
    main()
