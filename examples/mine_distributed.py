"""Distributed mining end-to-end: shard the database over a device mesh,
run a multi-pass phase per dispatch, checkpoint between phases, and resume
after a simulated failure.

  PYTHONPATH=src python examples/mine_distributed.py
"""

import shutil
import tempfile

from repro.core import mine, sequential_apriori
from repro.core.mapreduce import MapReduceRuntime
from repro.data import dataset_by_name


def main():
    txns, n_items = dataset_by_name("c20d10k", scale=0.1)
    runtime = MapReduceRuntime()  # all local devices along the `data` axis
    print(f"runtime: {runtime.n_data_shards} data shard(s), impl={runtime.impl}")

    ckpt = tempfile.mkdtemp(prefix="mine_ckpt_")
    try:
        # phase 1..2 only, then "crash"
        partial = mine(txns, n_items=n_items, min_sup=0.22,
                       algorithm="optimized_etdpc", runtime=runtime,
                       checkpoint_dir=ckpt, max_k=2)
        print(f"'crashed' after {partial.n_phases} phases "
              f"(checkpoint at k={max(partial.levels)})")

        # restart: resumes from the checkpoint, finishes the remaining levels
        full = mine(txns, n_items=n_items, min_sup=0.22,
                    algorithm="optimized_etdpc", runtime=runtime,
                    checkpoint_dir=ckpt, resume=True)
        print(f"resumed run finished: levels={sorted(full.levels)} "
              f"dispatches={full.dispatches}")

        oracle = sequential_apriori(txns, 0.22)
        assert full.itemsets() == oracle
        print("restart-consistency vs oracle ✓")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
