"""Cluster-scale mining end-to-end (DESIGN.md §11): lay a 2-D
(data, cand) mesh over every device, mine with elastic per-level
repartitioning, survive an injected shard failure via the retry protocol,
and resume from an inter-phase checkpoint — all bit-identical to the
sequential oracle.

Run with simulated devices to see the mesh in action on one host:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/mine_distributed.py --n-cand-shards 2

On a real cluster, start the same command on every worker with the
coordinator triple set (--coordinator host:port --num-processes N
--process-id i, or the JAX_* env vars) — `runtime_from_args` initializes
jax.distributed before building the mesh.
"""

import argparse
import shutil
import tempfile

from repro.core import mine, sequential_apriori
from repro.launch.cliopts import add_mesh_args, runtime_from_args


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--min-sup", type=float, default=0.22)
    add_mesh_args(ap)
    args = ap.parse_args()

    # import after arg parsing: runtime_from_args may init jax.distributed
    from repro.data import dataset_by_name
    txns, n_items = dataset_by_name("c20d10k", scale=0.1)

    runtime, mesh_kwargs = runtime_from_args(args)
    print(f"mesh: {runtime.mesh_split[0]} data x "
          f"{runtime.mesh_split[1]} cand shard(s), impl={runtime.impl}, "
          f"elastic={mesh_kwargs['elastic']}")

    ckpt = tempfile.mkdtemp(prefix="mine_ckpt_")
    try:
        # -- fault tolerance: fail the second counting job once; the driver
        # re-places the shards from the host copy and re-dispatches
        state = {"fired": False}

        def fail_once(event, k):
            if event == "count_dispatch" and k > 1 and not state["fired"]:
                state["fired"] = True
                raise RuntimeError("injected shard failure")

        partial = mine(txns, n_items=n_items, min_sup=args.min_sup,
                       algorithm="optimized_etdpc", runtime=runtime,
                       checkpoint_dir=ckpt, max_k=2,
                       count_hook=fail_once, **mesh_kwargs)
        print(f"'crashed' after {partial.n_phases} phases "
              f"(checkpoint at k={max(partial.levels)}); "
              f"survived {partial.retries} injected failure(s), "
              f"{partial.repartitions} elastic repartition(s)")

        # -- restart: resumes from the checkpoint, finishes the rest; the
        # controller re-prices the mesh split for the later (wider) levels
        full = mine(txns, n_items=n_items, min_sup=args.min_sup,
                    algorithm="optimized_etdpc", runtime=runtime,
                    checkpoint_dir=ckpt, resume=True, **mesh_kwargs)
        print(f"resumed run finished: levels={sorted(full.levels)} "
              f"dispatches={full.dispatches} "
              f"repartitions={full.repartitions}")

        oracle = sequential_apriori(txns, args.min_sup)
        assert full.itemsets() == oracle
        print("failure + restart consistency vs oracle ✓")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
