"""Guided tour: streaming incremental mining with live rule refresh.

  PYTHONPATH=src python examples/stream_mine.py

A market-basket stream flows through a sliding window: the StreamMiner keeps
the frequent itemsets *exact* at every step with O(delta) signed counting
(DESIGN.md §8), falls back to a full policy-driven re-mine when the itemset
structure drifts, and atomically swaps fresh association rules into the live
serving engine — so the recommendations below change as the stream's tastes
change, without ever re-loading the dataset.
"""

import numpy as np

from repro.core import mine
from repro.data import mushroom_like
from repro.stream import StreamMiner
from repro.stream.tables import levels_equal


def main():
    txns, n_items = mushroom_like(n_txns=1200, seed=5)
    rng = np.random.default_rng(5)

    miner = StreamMiner(n_items, min_sup=0.4, capacity=512, mode="sliding",
                        min_confidence=0.7, serve_kwargs={"top_k": 3})

    print("== prefill: first 512 transactions ==")
    rec = miner.push(txns[:512])
    print(f"  {rec.path}: {rec.n_frequent} frequent itemsets, "
          f"{rec.n_rules} rules in {rec.update_seconds:.2f}s")

    basket = list(txns[0][:-2])
    print(f"\nlive basket {basket[:6]}... recommends:")
    for r in miner.query([basket])[0]:
        print(f"  {r.consequent} (conf={r.confidence:.3f} lift={r.lift:.2f})")

    print("\n== stream 16 micro-batches of 16 ==")
    for u in range(16):
        lo = 512 + u * 16
        rec = miner.push(txns[lo:lo + 16])
        tag = "rules refreshed" if rec.levels_changed else "unchanged"
        print(f"  update {rec.seq:2d} [{rec.path:8s}] window={rec.window_size} "
              f"frequent={rec.n_frequent} rules={rec.n_rules} ({tag})")

    print("\n== shift the distribution (drop an attribute) ==")
    shifted = [[i for i in t if i >= 2] for t in txns[700:900]]
    for u in range(4):
        rec = miner.push(shifted[u * 32:(u + 1) * 32])
        print(f"  update {rec.seq:2d} [{rec.path:17s}] "
              f"frequent={rec.n_frequent} rules={rec.n_rules}")

    print("\nafter the shift, the same basket recommends:")
    for r in miner.query([basket])[0]:
        print(f"  {r.consequent} (conf={r.confidence:.3f} lift={r.lift:.2f})")

    # the equivalence oracle: incremental state == from-scratch mine, exactly
    scratch = mine(db_masks=miner.window.contents(), n_items=n_items,
                   min_sup=0.4)
    assert levels_equal(miner.levels, scratch.levels)
    print("\nincremental state verified byte-identical to a from-scratch mine "
          f"({miner.n_remines} re-mines across {len(miner.updates)} updates)")


if __name__ == "__main__":
    main()
