"""Mine → rules → recommend, end to end on a toy market-basket catalog.

  PYTHONPATH=src python examples/recommend.py

Synthesizes grocery transactions with embedded purchase patterns, mines
frequent itemsets with the paper's best algorithm, generates the vectorized
RuleSet (DESIGN.md §7) and serves named-item recommendation queries through
the RuleServeEngine.
"""

import numpy as np

from repro.core import generate_ruleset, mine
from repro.serving import RuleServeEngine

ITEMS = ["bread", "butter", "milk", "beer", "diapers", "crisps",
         "coffee", "sugar", "tea", "eggs", "cheese", "apples"]
PATTERNS = [  # (item names, popularity weight)
    (["bread", "butter", "milk"], 4),
    (["beer", "diapers", "crisps"], 3),
    (["coffee", "sugar"], 3),
    (["tea", "sugar"], 2),
    (["eggs", "cheese", "bread"], 2),
]


def synth_transactions(n=400, seed=0):
    rng = np.random.default_rng(seed)
    ids = {name: i for i, name in enumerate(ITEMS)}
    weights = np.array([w for _, w in PATTERNS], float)
    weights /= weights.sum()
    txns = []
    for _ in range(n):
        pat, _ = PATTERNS[rng.choice(len(PATTERNS), p=weights)]
        basket = {ids[x] for x in pat if rng.random() < 0.9}
        for x in ITEMS:           # a little browsing noise
            if rng.random() < 0.05:
                basket.add(ids[x])
        txns.append(sorted(basket) or [ids["bread"]])
    return txns


def names(ids_):
    return "{" + ", ".join(ITEMS[i] for i in ids_) + "}"


def main():
    txns = synth_transactions()
    res = mine(txns, n_items=len(ITEMS), min_sup=0.1,
               algorithm="optimized_vfpc")
    rules = generate_ruleset(res, min_confidence=0.6)
    print(f"{res.n_txns} baskets → "
          f"{sum(v[0].shape[0] for v in res.levels.values())} frequent "
          f"itemsets → {len(rules)} rules\n")

    print("top rules:")
    for rule in rules.to_rules(max_rules=5):
        print(f"  {names(rule.antecedent)} ⇒ {names(rule.consequent)}  "
              f"conf={rule.confidence:.2f} lift={rule.lift:.2f} "
              f"leverage={rule.leverage:.3f}")

    engine = RuleServeEngine(rules, top_k=3)
    queries = [["bread", "butter"], ["beer"], ["coffee"], ["tea"],
               ["eggs", "bread"]]
    ids = {name: i for i, name in enumerate(ITEMS)}
    recs = engine.query([[ids[x] for x in q] for q in queries])
    print("\nrecommendations:")
    for q, rr in zip(queries, recs):
        best = ", ".join(f"{names(r.consequent)} (conf={r.confidence:.2f})"
                         for r in rr) or "(none)"
        print(f"  basket {{{', '.join(q)}}} → {best}")


if __name__ == "__main__":
    main()
