"""Quickstart: mine frequent itemsets with the paper's best algorithm and
compare the seven MapReduce drivers.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import ALGORITHMS, generate_rules, mine, sequential_apriori
from repro.data import dataset_by_name, dataset_stats


def main():
    # 1) a dense mushroom-like dataset (the paper's hardest case)
    txns, n_items = dataset_by_name("mushroom", scale=0.05)
    print("dataset:", dataset_stats(txns, n_items))

    # 2) mine with Optimized-VFPC (the paper's headline algorithm)
    res = mine(txns, n_items=n_items, min_sup=0.4, algorithm="optimized_vfpc")
    print(f"\noptimized_vfpc: {res.n_phases} phases, "
          f"{res.dispatches} MapReduce jobs, {res.total_seconds:.2f}s")
    for ph in res.phases:
        print(f"  levels {ph.k_start}..{ph.k_start+ph.npass-1}: "
              f"candidates={ph.candidate_counts} frequent={ph.frequent_counts}")

    # 3) verify against the sequential oracle
    oracle = sequential_apriori(txns, 0.4)
    assert res.itemsets() == oracle
    print("\nmatches sequential Apriori ✓")

    # 4) compare all seven algorithms (the paper's Figs. 2–4 in miniature)
    print(f"\n{'algorithm':<18} {'jobs':>5} {'phases':>7} {'seconds':>8}")
    for algo in sorted(ALGORITHMS):
        r = mine(txns, n_items=n_items, min_sup=0.4, algorithm=algo)
        assert r.itemsets() == oracle, algo
        print(f"{algo:<18} {r.dispatches:>5} {r.n_phases:>7} "
              f"{r.total_seconds:>8.2f}")

    # 5) association rules from the mined itemsets (the ARM endgame)
    rules = generate_rules(res, min_confidence=0.6, max_rules=5)
    print(f"\ntop association rules (min_conf=0.6): {len(rules)} shown")
    for rule in rules:
        print("  ", rule)


if __name__ == "__main__":
    main()
