"""Serve a small model with batched requests and paper-policy multi-step
decode fusion — the serving-layer application of the paper's technique.

Ragged prompts (continuous batching), EOS handling with skipped-pruning
("optimized" engines trim post-EOS tokens at phase end), and a policy
comparison showing dispatch amortization.

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import ServeEngine


def main():
    cfg = get_config("smollm-135m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    B, max_len, max_new = 8, 12, 48
    lens = rng.integers(4, max_len + 1, B).astype(np.int32)
    prompts = np.zeros((B, max_len), np.int32)
    for i, l in enumerate(lens):
        prompts[i, :l] = rng.integers(1, cfg.vocab_size, l)

    print(f"{B} requests, prompt lens {lens.tolist()}, {max_new} new tokens\n")
    print(f"{'policy':<18} {'dispatches':>10} {'widths'}")
    outs = {}
    for algo in ["spc", "fpc", "vfpc", "etdpc", "optimized_vfpc"]:
        eng = ServeEngine(model, params, cache_len=max_len + max_new + 8,
                          algorithm=algo)
        t0 = time.perf_counter()
        toks, recs = eng.generate(prompts, prompt_lens=lens,
                                  max_new_tokens=max_new, eos_id=-1)
        wall = time.perf_counter() - t0
        outs[algo] = toks
        widths = [r.npass for r in recs]
        print(f"{algo:<18} {len(recs):>10} {widths}  ({wall:.2f}s)")

    base = outs["spc"]
    assert all((v == base).all() for v in outs.values())
    print("\nall policies produced identical tokens ✓")
    print("request 0 continuation:", base[0][:16].tolist())


if __name__ == "__main__":
    main()
